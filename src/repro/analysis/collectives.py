"""Structured collective IR over post-SPMD HLO + physical topology mapping.

``launch/hlo.py`` answers "how many bytes of collectives" — this module
answers *which* collectives: one :class:`CollectiveOp` per HLO collective
with its resolved replica groups (actual partition-id lists, materialized
from both iota ``[G,S]<=[dims]T(perm)`` and explicit ``{{0,1},{2,3}}``
forms), result bytes, and the trip-count multiplier of its enclosing
scan/while loops (``hlo_cost.computation_multipliers``), so a collective
inside a 48-layer scan counts 48 times.

:class:`DeviceTopology` maps partition ids onto the physical hierarchy
(node -> zone) so each replica group can be classified as ``intra-node``,
``intra-zone`` or ``cross-zone`` — the domain the simulator would have to
price it in.  NOTE: HLO replica groups hold *partition ids*, i.e. indices
into the mesh's flattened device array, not ``Device.id`` — build the
topology with :meth:`DeviceTopology.from_mesh`, which indexes by flat
position.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.launch import hlo as hlo_mod
from repro.launch import hlo_cost

INTRA_NODE = "intra-node"
INTRA_ZONE = "intra-zone"
CROSS_ZONE = "cross-zone"

_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_LIST_RE = re.compile(r"replica_groups=\{\{(.*?)\}\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{\{(.*?)\}\}")


def _parse_iota(g: int, s: int, dims: Sequence[int],
                perm: Optional[Sequence[int]]) -> Tuple[Tuple[int, ...], ...]:
    """Materialize an iota replica-group list without numpy: ids
    0..prod(dims)-1 laid out over ``dims``, transposed by ``perm``,
    reshaped to (g, s)."""
    n = math.prod(dims)
    if perm:
        strides = [0] * len(dims)
        acc = 1
        for i in range(len(dims) - 1, -1, -1):
            strides[i] = acc
            acc *= dims[i]
        out_dims = [dims[p] for p in perm]
        flat: List[int] = []

        def walk(depth: int, coords: List[int]):
            if depth == len(out_dims):
                flat.append(sum(c * strides[perm[i]]
                                for i, c in enumerate(coords)))
                return
            for c in range(out_dims[depth]):
                walk(depth + 1, coords + [c])

        walk(0, [])
    else:
        flat = list(range(n))
    return tuple(tuple(flat[i * s:(i + 1) * s]) for i in range(g))


def parse_replica_groups(line: str) -> Tuple[Tuple[int, ...], ...]:
    """All replica groups of one HLO collective line, as partition-id
    tuples.  ``source_target_pairs`` yields one (src, tgt) group per pair.
    Empty when the op carries no grouping annotation (flat world group —
    callers may substitute ``range(n_partitions)``)."""
    m = _IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",") if d]
        perm = [int(p) for p in m.group(4).split(",")] if m.group(4) else None
        return _parse_iota(g, s, dims, perm)
    m = _LIST_RE.search(line)
    if m:
        return tuple(
            tuple(int(x) for x in grp.split(",") if x.strip() != "")
            for grp in m.group(1).split("},{"))
    m = _PAIRS_RE.search(line)
    if m:
        return tuple(
            tuple(int(x) for x in grp.split(",") if x.strip() != "")
            for grp in m.group(1).split("},{"))
    return ()


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective in the post-SPMD program."""
    name: str                     # HLO op name
    kind: str                     # base kind: all-reduce, all-gather, ...
    phase: Optional[str]          # "-start" | None (done forms are skipped)
    computation: str              # enclosing HLO computation
    nbytes: int                   # result bytes (output buffer only)
    group_size: int
    groups: Tuple[Tuple[int, ...], ...]   # resolved partition-id groups
    trip_mult: float              # product of enclosing known_trip_counts
    unknown_dtypes: Tuple[str, ...] = ()

    @property
    def traffic(self) -> float:
        """Ring-scaled wire bytes of ONE execution."""
        return hlo_mod.ring_traffic(self.kind, self.nbytes, self.group_size)

    @property
    def total_traffic(self) -> float:
        """Ring-scaled wire bytes over the whole step (trip-weighted)."""
        return self.traffic * self.trip_mult


def extract_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Every collective reachable from the entry computation, with replica
    groups resolved and trip-count multipliers applied.  ``-done`` halves
    of split-phase pairs are skipped (the ``-start`` op carries the shape);
    computations never called (multiplier 0) contribute nothing."""
    comps, entry = hlo_cost.parse_computations(hlo_text)
    mult = hlo_cost.computation_multipliers(comps, entry)
    out: List[CollectiveOp] = []
    for cname in sorted(comps):
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for op in comps[cname].ops.values():
            base = op.kind.replace("-start", "").replace("-done", "")
            if base not in hlo_mod._COLL or op.kind.endswith("-done"):
                continue
            phase = "-start" if op.kind.endswith("-start") else None
            nbytes, unk = hlo_mod.result_bytes(op.shape_str, phase)
            groups = parse_replica_groups(op.line)
            k = max((len(g) for g in groups), default=0) \
                or hlo_mod.group_size(op.line)
            out.append(CollectiveOp(
                name=op.name, kind=base, phase=phase, computation=cname,
                nbytes=nbytes, group_size=k, groups=groups, trip_mult=m,
                unknown_dtypes=tuple(unk)))
    return out


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """Partition id -> physical location (node, zone).

    ``zones[p]`` is the zone of partition ``p``; nodes are contiguous
    ``chips_per_node`` runs of partition ids (how the launcher packs
    hosts).  Built from a mesh via :meth:`from_mesh` or given explicitly
    in tests.
    """
    zones: Tuple[str, ...]
    chips_per_node: int = 4

    @property
    def n_devices(self) -> int:
        return len(self.zones)

    def zone_of(self, p: int) -> str:
        return self.zones[p] if 0 <= p < len(self.zones) else f"?{p}"

    def node_of(self, p: int) -> int:
        return p // max(1, self.chips_per_node)

    def domain(self, group: Sequence[int]) -> str:
        """Widest link class a replica group spans."""
        zs = {self.zone_of(p) for p in group}
        if len(zs) > 1:
            return CROSS_ZONE
        nodes = {self.node_of(p) for p in group}
        return INTRA_NODE if len(nodes) <= 1 else INTRA_ZONE

    def op_domain(self, op: CollectiveOp) -> str:
        """Widest domain across all of an op's replica groups."""
        order = (INTRA_NODE, INTRA_ZONE, CROSS_ZONE)
        worst = INTRA_NODE
        for g in op.groups:
            d = self.domain(g)
            if order.index(d) > order.index(worst):
                worst = d
        return worst

    @classmethod
    def from_mesh(cls, mesh, zone_axes: Sequence[str] = ("pod",),
                  chips_per_node: int = 4) -> "DeviceTopology":
        """Topology of a JAX mesh: partition id = flat position in
        ``mesh.devices`` (C order — matches the SPMD device assignment),
        zone = the device's coordinates along ``zone_axes`` (the 'pod'
        axis crosses DCN/zones in this repo's production meshes)."""
        import numpy as np
        devs = np.asarray(mesh.devices)
        names = list(mesh.axis_names)
        zidx = [names.index(a) for a in zone_axes if a in names]
        zones: List[str] = []
        for coords in np.ndindex(devs.shape):
            key = tuple(coords[i] for i in zidx)
            zones.append("zone-" + "-".join(map(str, key)) if key
                         else "zone-0")
        return cls(zones=tuple(zones), chips_per_node=chips_per_node)


def volumes_by_kind(ops: Sequence[CollectiveOp],
                    topology: Optional[DeviceTopology] = None,
                    min_bytes: int = 0) -> Dict[str, Dict]:
    """Aggregate trip-weighted traffic per op kind (and per domain when a
    topology is given).  Ops smaller than ``min_bytes`` (control scalars,
    e.g. the f32[] loss all-reduce) are excluded."""
    out: Dict[str, Dict] = {}
    for op in ops:
        if op.nbytes < min_bytes:
            continue
        rec = out.setdefault(op.kind, {"count": 0, "bytes": 0.0,
                                       "traffic": 0.0, "domains": {}})
        rec["count"] += 1
        rec["bytes"] += op.nbytes * op.trip_mult
        rec["traffic"] += op.total_traffic
        if topology is not None:
            dom = topology.op_domain(op)
            rec["domains"][dom] = rec["domains"].get(dom, 0.0) \
                + op.total_traffic
    return out
