"""Repo invariant linter: AST rules over the planner/simulator sources.

The planner's correctness rests on invariants that code review keeps
re-litigating; this makes them mechanical.  Rules (scoped to the paths
where the invariant holds — DESIGN.md §15 has the full table):

==================  ===========================  =========================
rule                scope                        invariant
==================  ===========================  =========================
wallclock           core/planner, core/simulator  no ``time.time()`` /
                                                  ``time.time_ns()`` in
                                                  pure search/simulate
                                                  paths — plans must be
                                                  byte-identical across
                                                  runs (PR 5).  (``perf_
                                                  counter`` for *stats*
                                                  fields is allowed: it
                                                  never feeds plan
                                                  content.)
unseeded-random     core/planner, core/simulator  no module-level
                                                  ``random.*`` /
                                                  ``np.random.*`` calls —
                                                  randomness must flow
                                                  through a seeded
                                                  ``default_rng``/``Random``
set-iteration       core/planner, core/simulator  no iteration directly
                                                  over ``set``-typed
                                                  expressions (literals,
                                                  ``set()``/``frozenset()``
                                                  calls, set ops) — order
                                                  is hash-seed dependent
                                                  and leaks into plan
                                                  tie-breaks.  Dicts are
                                                  insertion-ordered and
                                                  exempt.
mem-feasibility     core/planner                  feasibility comparisons
                                                  must go through
                                                  ``stage_peak_bytes`` /
                                                  ``usable_mem_bytes``,
                                                  never raw ``.mem_bytes``
                                                  (PR 4: reserved HBM).
==================  ===========================  =========================

Suppression: append ``# lint: disable=<rule>[,<rule>...]`` to the
offending line, or put ``# lint: disable-file=<rule>`` on any line to
waive a rule for the whole file (both are themselves reported with
``--show-suppressed``).

CLI::

    PYTHONPATH=src python -m repro.analysis.lint src/
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import os
import re
import sys
from typing import Dict, List, Sequence, Tuple

ALL_RULES = ("wallclock", "unseeded-random", "set-iteration",
             "mem-feasibility")

# rule -> path fragments (posix) it applies to
_SCOPES: Dict[str, Tuple[str, ...]] = {
    "wallclock": ("core/planner/", "core/simulator/"),
    "unseeded-random": ("core/planner/", "core/simulator/"),
    "set-iteration": ("core/planner/", "core/simulator/"),
    "mem-feasibility": ("core/planner/",),
}

_WALLCLOCK_FNS = {"time", "time_ns"}
_SEEDED_RANDOM_FNS = {"default_rng", "Random", "RandomState", "PRNGKey"}
_SET_BINOPS = (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)

_DISABLE_LINE = re.compile(r"#\s*lint:\s*disable=([\w,\-]+)")
_DISABLE_FILE = re.compile(r"#\s*lint:\s*disable-file=([\w,\-]+)")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str
    suppressed: bool = False

    def render(self) -> str:
        sup = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{sup}"


def _dotted(node: ast.AST) -> str:
    """'np.random.shuffle' for an Attribute/Name chain, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, _SET_BINOPS):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


class _Checker(ast.NodeVisitor):
    def __init__(self, path: str, rules: Sequence[str]):
        self.path = path
        self.rules = set(rules)
        self.out: List[Violation] = []

    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        if rule in self.rules:
            self.out.append(Violation(self.path, node.lineno, rule, msg))

    # --- wallclock / unseeded-random (both look at calls) ------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if name in ("time.time", "time.time_ns"):
            self._emit("wallclock", node,
                       f"{name}() in a pure planner/simulator path breaks "
                       f"byte-identical-plan determinism; thread a clock "
                       f"in or move timing to the caller")
        # jax.random.* is exempt: every call takes an explicit PRNG key
        parts = name.split(".")
        if len(parts) >= 2 and parts[-2] == "random" \
                and parts[0] in ("random", "np", "numpy") \
                and parts[-1] not in _SEEDED_RANDOM_FNS:
            self._emit("unseeded-random", node,
                       f"{name}() draws from global (unseeded) state; use "
                       f"a seeded default_rng/Random instance")
        elif len(parts) == 2 and parts[0] == "random" \
                and parts[1] not in _SEEDED_RANDOM_FNS:
            self._emit("unseeded-random", node,
                       f"{name}() draws from the global random module; "
                       f"use a seeded Random instance")
        self.generic_visit(node)

    # --- set-iteration ------------------------------------------------------
    def _check_iter(self, it: ast.AST) -> None:
        if _is_set_expr(it):
            self._emit("set-iteration", it,
                       "iteration over a set is hash-order dependent and "
                       "leaks into tie-breaks; wrap in sorted(...)")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter)
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    # --- mem-feasibility ----------------------------------------------------
    def visit_Compare(self, node: ast.Compare) -> None:
        for side in [node.left, *node.comparators]:
            if isinstance(side, ast.Attribute) and side.attr == "mem_bytes":
                self._emit(
                    "mem-feasibility", node,
                    "feasibility check against raw .mem_bytes ignores the "
                    "runtime's reserved HBM; route through "
                    "stage_peak_bytes / usable_mem_bytes")
                break
        self.generic_visit(node)


def _rules_for(path: str) -> List[str]:
    posix = path.replace(os.sep, "/")
    return [r for r, frags in _SCOPES.items()
            if any(f in posix for f in frags)]


def lint_file(path: str, rules: Sequence[str] = None) -> List[Violation]:
    """Lint one file.  ``rules`` overrides the path-based scoping (used by
    tests); by default a file outside every rule's scope yields nothing."""
    rules = list(rules) if rules is not None else _rules_for(path)
    if not rules:
        return []
    with open(path) as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 0, "parse-error", str(e))]
    checker = _Checker(path, rules)
    checker.visit(tree)
    # apply suppression comments
    lines = src.splitlines()
    file_off = set()
    for ln in lines:
        m = _DISABLE_FILE.search(ln)
        if m:
            file_off.update(m.group(1).split(","))
    out: List[Violation] = []
    for v in checker.out:
        line_txt = lines[v.line - 1] if 0 < v.line <= len(lines) else ""
        m = _DISABLE_LINE.search(line_txt)
        line_off = set(m.group(1).split(",")) if m else set()
        out.append(dataclasses.replace(
            v, suppressed=v.rule in file_off | line_off))
    return out


def lint_paths(paths: Sequence[str],
               rules: Sequence[str] = None) -> List[Violation]:
    """Lint every ``.py`` file under ``paths`` (files or directories)."""
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, _dirs, names in os.walk(p):
                files.extend(os.path.join(root, n)
                             for n in sorted(names) if n.endswith(".py"))
    out: List[Violation] = []
    for f in sorted(set(files)):
        out.extend(lint_file(f, rules))
    return out


def main(argv: Sequence[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo invariant linter (DESIGN.md §15)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--rules", default=None,
                    help=f"comma-separated subset of {ALL_RULES}")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print violations waived by disable comments")
    args = ap.parse_args(argv)
    rules = args.rules.split(",") if args.rules else None
    if rules:
        unknown = set(rules) - set(ALL_RULES)
        if unknown:
            ap.error(f"unknown rules {sorted(unknown)}; known: {ALL_RULES}")
    vs = lint_paths(args.paths or ["src"], rules)
    active = [v for v in vs if not v.suppressed]
    shown = vs if args.show_suppressed else active
    for v in shown:
        print(v.render())
    n_sup = sum(v.suppressed for v in vs)
    print(f"lint: {len(active)} violation(s), {n_sup} suppressed")
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
