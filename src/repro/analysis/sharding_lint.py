"""Static sharding lint: catch silent replication before compiling.

``dist/sharding.py`` resolves logical axes to PartitionSpecs with a
divisibility fallback: a dim that does not divide its mesh axis silently
replicates.  That is the right runtime behavior (no padding, no partial
shards) — and exactly the kind of silent degradation that makes a plan's
memory/comm model wrong.  These rules re-run the resolution statically
and report what fell back:

* ``ReplicatedLargeTensor`` — a tensor at least ``large_bytes`` big whose
  resolved spec is fully replicated.  ERROR when a policy rule *tried* to
  shard it (divisibility fallback fired: the planner thinks it is sharded
  over 'model' but every chip holds a full copy); WARNING when the policy
  simply has no rule for its axes (declared, never shardable).
* ``BatchReplicated`` — ``batch_spec`` resolved the batch dim to None
  while the mesh has dp axes: every data-parallel replica computes the
  same examples, i.e. the job silently stopped being data-parallel.

Run via :func:`lint_decls` / :func:`lint_batch` on the same (decls,
policy, mesh) triple the model builder uses.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax

from repro.analysis.findings import ERROR, WARNING, Report
from repro.dist import sharding as sh


def _nbytes(decl: sh.Decl, dtype_bytes: int) -> int:
    return math.prod(decl.shape) * dtype_bytes if decl.shape else dtype_bytes


def _path_str(path) -> str:
    parts = []
    for p in path:
        key = getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))
        parts.append(str(key))
    return "/".join(parts) or "<root>"


def lint_decls(decls: Any, policy: str, mesh, *,
               large_bytes: int = 1 << 20,
               dtype_bytes: int = 2,
               tag: str = "sharding-lint") -> Report:
    """Lint a pytree of :class:`~repro.dist.sharding.Decl` against one
    (policy, mesh).  ``large_bytes`` is the replication-cost threshold at
    ``dtype_bytes``-wide parameters (default 1 MiB at bf16)."""
    rules = sh.policy_rules(policy)
    sizes = dict(mesh.shape)
    report = Report(tag=tag)
    leaves = jax.tree_util.tree_flatten_with_path(
        decls, is_leaf=lambda x: isinstance(x, sh.Decl))[0]
    n_large = n_replicated = 0
    for path, decl in leaves:
        if not isinstance(decl, sh.Decl):
            continue
        nbytes = _nbytes(decl, dtype_bytes)
        if nbytes < large_bytes:
            continue
        n_large += 1
        spec = sh.logical_to_spec(decl.shape, decl.axes, rules, mesh)
        if any(p is not None for p in tuple(spec)):
            continue
        n_replicated += 1
        where = _path_str(path)
        # which axes *tried* to shard (had a candidate on this mesh) and
        # lost to divisibility?
        fallbacks = []
        for dim, ax in zip(decl.shape, decl.axes):
            for cand in (rules.get(ax, ()) if ax else ()):
                if cand in sizes and dim % sizes[cand] != 0:
                    fallbacks.append((ax, cand, dim, sizes[cand]))
        if fallbacks:
            ax, cand, dim, n = fallbacks[0]
            report.add(
                "ReplicatedLargeTensor", ERROR,
                f"{where} ({nbytes / 1e6:.1f} MB) degraded to full "
                f"replication: logical axis {ax!r} dim {dim} does not "
                f"divide mesh axis {cand!r}={n} (divisibility fallback)",
                where=where, nbytes=nbytes, shape=list(decl.shape),
                axes=list(decl.axes),
                fallbacks=[list(f) for f in fallbacks])
        else:
            report.add(
                "ReplicatedLargeTensor", WARNING,
                f"{where} ({nbytes / 1e6:.1f} MB) is fully replicated: "
                f"policy {policy!r} has no rule sharding any of its axes "
                f"on this mesh",
                where=where, nbytes=nbytes, shape=list(decl.shape),
                axes=list(decl.axes))
    report.summary = {"policy": policy, "mesh": dict(sizes),
                      "n_decls": len(leaves), "n_large": n_large,
                      "n_replicated_large": n_replicated}
    return report


def lint_batch(mesh, global_batch: int, *,
               tag: str = "batch-lint") -> Report:
    """Check the batch dim actually shards over the dp axes of ``mesh``."""
    report = Report(tag=tag)
    axes = sh.dp_axes(mesh)
    sizes = dict(mesh.shape)
    spec = sh.batch_spec(mesh, global_batch)
    first = tuple(spec)[0] if len(tuple(spec)) else None
    if axes and first is None:
        dp_total = math.prod(sizes[a] for a in axes)
        report.add(
            "BatchReplicated", ERROR,
            f"global batch {global_batch} shards over none of the dp axes "
            f"{list(axes)} (sizes {[sizes[a] for a in axes]}): every "
            f"data-parallel replica would compute identical examples",
            batch=global_batch, dp_axes=list(axes), dp_total=dp_total)
    sharded_over = (first,) if isinstance(first, str) else tuple(first or ())
    report.summary = {"batch": global_batch, "dp_axes": list(axes),
                      "batch_sharded_over": list(sharded_over)}
    return report
