import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks at first backend init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding configuration is coherent (no partitioner errors),
  * the per-device memory fits the target chip's HBM (``memory_analysis``
    against the ``--chip`` catalog entry, default tpu-v5e),
  * and it extracts the §Roofline terms: per-device FLOPs/bytes from
    ``cost_analysis`` + collective traffic parsed from the post-SPMD HLO.

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and are
consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--out artifacts/dryrun]
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch import hlo as hlo_mod
from repro.launch import hlo_cost
from repro.launch import shapes as shapes_mod
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.models.config import SHAPES
from repro.core.profiler.hw_specs import AcceleratorSpec, get_accelerator
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib

# Dry-runs price against the reproduction target by default; --chip swaps
# the whole roofline to any catalog entry (hw_specs.ACCELERATORS).
DEFAULT_CHIP = "tpu-v5e"


def step_fn_for(cell: shapes_mod.Cell, mesh):
    cfg = cell.cfg
    if cell.kind == "train":
        opt_cfg = opt_lib.OptimizerConfig()
        return ts_lib.make_train_step(cfg, opt_cfg, mesh)
    if cell.kind == "prefill":
        def prefill(params, batch):
            logits, cache = model_lib.forward(cfg, params, batch, mesh=mesh,
                                              return_cache=True)
            return logits[:, -1], cache
        return prefill

    def decode(params, cache, tokens):
        logits, cache = model_lib.decode(cfg, params, cache, tokens,
                                         mesh=mesh)
        return logits[:, -1], cache
    return decode


def _audit_cell(cfg, cell, mesh, hlo_text: str, tag: str) -> Dict:
    """Collective audit of one compiled train cell: diff the HLO's
    trip-weighted collective volumes against the simulator's predicted
    comm terms (``analysis.audit.predicted_comm``).  Advisory — the
    report rides on the artifact; ``repro.analysis.demo`` is the CI
    pass/fail gate."""
    from repro.analysis import audit as audit_mod
    from repro.analysis import collectives as coll_mod
    from repro.core.profiler.analytic import JobProfile, TrainJob
    sizes = dict(mesh.shape)
    tp = int(sizes.get("model", 1))
    dp = 1
    for a in ("pod", "data"):
        dp *= int(sizes.get(a, 1))
    n_micro = max(1, int(cell.num_microbatches or 1))
    mbs = max(1, cell.shape.global_batch // (dp * n_micro))
    job = TrainJob(cfg=cfg, seq_len=cell.shape.seq_len,
                   global_batch=cell.shape.global_batch)
    predicted = audit_mod.predicted_comm(JobProfile(job), tp=tp, dp=dp,
                                         mbs=mbs, n_micro=n_micro)
    topo = coll_mod.DeviceTopology.from_mesh(mesh, zone_axes=("pod",))
    return audit_mod.audit_hlo(hlo_text, topo, predicted,
                               tag=tag).to_dict()


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, mesh=None, overrides: Optional[Dict] = None,
             tag: str = "", chip: str = DEFAULT_CHIP,
             audit: bool = False) -> Dict:
    acc: AcceleratorSpec = get_accelerator(chip)
    cfg = get_config(arch)
    nm_override = 0
    if overrides:
        overrides = dict(overrides)
        nm_override = overrides.pop("num_microbatches", 0)
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chip": chip,
                 "mesh_shape": dict(mesh.shape), "ok": False, "tag": tag,
                 "overrides": dict(overrides or {},
                                   **({"num_microbatches": nm_override}
                                      if nm_override else {}))}
    t0 = time.perf_counter()
    try:
        cell = shapes_mod.build_cell(cfg, shape_name, mesh,
                                     nm_override=nm_override)
        if cell.skip_reason:
            rec.update(ok=True, skipped=True, skip_reason=cell.skip_reason)
            return _save(rec, out_dir)
        rec["num_microbatches"] = cell.num_microbatches
        step = step_fn_for(cell, mesh)
        with jax.set_mesh(mesh):
            lowered = jax.jit(step).lower(*cell.args)
            t_lower = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter()
        from repro.core.profiler.measured import xla_peak_bytes
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):     # older jax: list of dicts
            cost = cost[0] if cost else {}
        txt = compiled.as_text()
        # trip-count-aware accounting (XLA's cost_analysis counts while
        # bodies once — useless for scan-over-layers programs; see hlo_cost)
        scaled = hlo_cost.analyze(txt)
        colls = hlo_mod.collective_bytes(txt)      # raw, body-once (kept)
        n_chips = int(len(mesh.devices.reshape(-1)))
        flops_dev = scaled.flops
        bytes_dev = scaled.bytes_accessed
        per_dev_mem = xla_peak_bytes(compiled)
        # roofline terms (per device == per chip; see DESIGN.md §8),
        # priced from the accelerator catalog entry for ``chip``
        t_comp = flops_dev / acc.peak_flops
        t_mem = bytes_dev / acc.mem_bw
        t_coll = scaled.collective_traffic / acc.collective_link_bw
        tokens = cell.shape.global_batch * (
            cell.shape.seq_len if cell.kind != "decode" else 1)
        model_flops = 6 * cfg.active_params() * tokens if cell.kind == "train" \
            else 2 * cfg.active_params() * tokens
        rec.update(
            ok=True, skipped=False,
            lower_s=t_lower - t0, compile_s=t_compile - t_lower,
            n_chips=n_chips,
            per_device={
                "flops": flops_dev,
                "bytes_accessed": bytes_dev,
                "flops_xla_body_once": float(cost.get("flops", 0.0)),
                "bytes_xla_body_once": float(cost.get("bytes accessed", 0.0)),
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_bytes": per_dev_mem,
            },
            fits_hbm=bool(per_dev_mem <= acc.mem_bytes),
            collectives={k: {"traffic": v} for k, v in
                         scaled.collective_by_kind.items()},
            collectives_raw={k: {"count": v[0], "bytes": v[1],
                                 "traffic": v[2]}
                             for k, v in colls.by_kind.items()},
            roofline={
                "compute_s": t_comp,
                "memory_s": t_mem,
                "collective_s": t_coll,
                # multi-pod upper bound: all collective traffic priced at
                # DCN bandwidth (pod-axis attribution is in EXPERIMENTS.md)
                "collective_dcn_s": (scaled.collective_traffic
                                     / acc.cross_pod_bw
                                     if multi_pod else None),
                "dominant": max(
                    [("compute", t_comp), ("memory", t_mem),
                     ("collective", t_coll)], key=lambda kv: kv[1])[0],
            },
            model_flops_total=model_flops,
            hlo_flops_total=flops_dev * n_chips,
            useful_flops_ratio=(model_flops / (flops_dev * n_chips)
                                if flops_dev else None),
        )
        if audit and cell.kind == "train":
            rec["audit"] = _audit_cell(
                cfg, cell, mesh, txt,
                tag=f"{arch}__{shape_name}__{mesh_name}")
    except Exception as e:     # a failing cell is a bug — record it loudly
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return _save(rec, out_dir)


def _save(rec: Dict, out_dir: str) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        out_dir,
        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides k=v (int/float/str), e.g. "
                         "moe_dispatch=per_seq logits_chunk=512")
    ap.add_argument("--tag", default="",
                    help="artifact suffix for variant runs")
    ap.add_argument("--chip", default=DEFAULT_CHIP,
                    help="accelerator catalog entry to price the roofline "
                         "against (hw_specs.ACCELERATORS)")
    ap.add_argument("--audit", action="store_true",
                    help="run the collective auditor (repro.analysis) on "
                         "each train cell and record the report in the "
                         "artifact (advisory; the CI gate is "
                         "repro.analysis.demo)")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v
    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    # build each mesh once (512 host devices exist either way)
    mesh_cache = {mp: make_production_mesh(multi_pod=mp) for mp in meshes}
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.perf_counter()
                rec = run_cell(arch, shape, mp, args.out,
                               mesh=mesh_cache[mp], overrides=overrides,
                               tag=args.tag, chip=args.chip,
                               audit=args.audit)
                dt = time.perf_counter() - t0
                if rec.get("skipped"):
                    status = "SKIP"
                elif rec["ok"]:
                    status = ("OK  " if rec.get("fits_hbm") else "OK!M")
                else:
                    status = "FAIL"
                    failures += 1
                dom = rec.get("roofline", {}).get("dominant", "-")
                mem_gb = rec.get("per_device", {}).get("peak_bytes", 0) / 1e9
                print(f"[{status}] {arch:15s} {shape:12s} "
                      f"{'multi' if mp else 'single':6s} {dt:7.1f}s "
                      f"mem={mem_gb:6.2f}GB dom={dom}", flush=True)
                if status == "FAIL":
                    print("   ", rec.get("error"), flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells FAILED")
    print("all requested dry-run cells passed")


if __name__ == "__main__":
    main()
