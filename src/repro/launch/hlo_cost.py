"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts scan-over-layers / microbatch-scan programs by orders of
magnitude (a 52-layer x 8-microbatch train step would be ~400x off).  This
module re-derives FLOPs / memory traffic / collective traffic from the
post-SPMD HLO text, walking the call graph and multiplying every
computation's cost by the product of enclosing ``known_trip_count``s.

Cost model per op (per-device, post-partitioning shapes):
  * dot:            2 * prod(output dims) * prod(lhs contracting dims)
  * bytes accessed: sum(operand bytes) + output bytes for every non-trivial
                    op (approximates XLA's bytes-accessed metric)
  * collectives:    ring-scaled traffic as in hlo.collective_bytes, but
                    weighted by the enclosing trip count.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops that do not move HBM bytes themselves (control/aliasing/loop glue)
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "while", "conditional", "call", "partition-id",
               "after-all", "custom-call"}


def _shape_info(s: str) -> Tuple[int, int]:
    """(total elements*dtype bytes, 0) for possibly-tuple shape strings."""
    total = 0
    for dt, dims in _SHAPE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total, 0


def _dims(s: str) -> List[int]:
    m = _SHAPE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    shape_str: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, OpInfo]
    param_shapes: Dict[str, str]

    def op_shape(self, ref: str) -> Optional[str]:
        ref = ref.strip().lstrip("%")
        if ref in self.ops:
            return self.ops[ref].shape_str
        if ref in self.param_shapes:
            return self.param_shapes[ref]
        return None


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_traffic: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)


_COMMENT = re.compile(r"/\*.*?\*/")


def parse_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        # strip /*index=N*/ tuple-position comments — they contain '=' and
        # break op-line matching
        line = _COMMENT.sub("", raw).rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and "{" in line:
            name = hdr.group(1)
            params: Dict[str, str] = {}
            for p in hdr.group(2).split(","):
                p = p.strip()
                if ":" in p:
                    pname, pshape = p.split(":", 1)
                    params[pname.strip().lstrip("%")] = pshape.strip()
            cur = Computation(name=name, ops={}, param_shapes=params)
            comps[name] = cur
            if line.strip().startswith("ENTRY"):
                entry = name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            name, shape_str, kind = m.group(1), m.group(2), m.group(3)
            cur.ops[name] = OpInfo(name, kind, shape_str, line)
    return comps, entry or ""


def _operand_tokens(op: OpInfo) -> List[str]:
    """Top-level comma split of the operand list after ``kind(``.

    Operands may carry inline shapes (``dot(f32[64,64]{1,0} %x, ...)``)
    whose brackets/braces/tuple parens contain commas of their own.
    """
    after = op.line.split(op.kind + "(", 1)
    if len(after) < 2:
        return []
    tokens, cur, depth = [], [], 0
    for ch in after[1]:
        if ch == ")" and depth == 0:
            break
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            tokens.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur and "".join(cur).strip():
        tokens.append("".join(cur).strip())
    return tokens


def _token_shape(comp: Computation, token: str) -> Optional[str]:
    """Shape of one operand token: inline if present, else by-name lookup."""
    if "[" in token:
        return token
    return comp.op_shape(token.split()[-1] if token.split() else token)


def _dot_flops(comp: Computation, op: OpInfo) -> float:
    out_dims = _dims(op.shape_str)
    cm = _CONTRACT.search(op.line)
    args = _operand_tokens(op)
    lhs_shape = _token_shape(comp, args[0]) if args else None
    contract = 1
    if cm and lhs_shape is not None:
        ldims = _dims(lhs_shape)
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(ldims):
                contract *= ldims[int(idx)]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contract


def _operand_shapes(comp: Computation, op: OpInfo) -> List[str]:
    out = []
    for a in _operand_tokens(op)[:8]:
        s = _token_shape(comp, a)
        if s:
            out.append(s)
    return out

# ops with slicing semantics: traffic ~ slice size, NOT full operands
_SLICE_READS = {"dynamic-slice", "gather", "slice"}
_SLICE_WRITES = {"dynamic-update-slice", "scatter"}


def _op_bytes(comp: Computation, op: OpInfo,
              comps: Optional[Dict[str, "Computation"]] = None) -> float:
    if op.kind in _SKIP_BYTES:
        return 0.0
    out_b, _ = _shape_info(op.shape_str)
    if op.kind in _SLICE_READS:
        return 2.0 * out_b                       # read slice + write out
    ops_shapes = _operand_shapes(comp, op)
    if op.kind in _SLICE_WRITES:
        # operand[1] (update for DUS) / operand[2] (updates for scatter)
        idx = 1 if op.kind == "dynamic-update-slice" else min(
            2, len(ops_shapes) - 1)
        upd = _shape_info(ops_shapes[idx])[0] if 0 <= idx < len(ops_shapes) \
            else out_b
        return 3.0 * upd                         # read buf slice+upd, write
    if op.kind == "fusion" and comps is not None:
        bm = _CALLS.search(op.line)
        body = comps.get(bm.group(1)) if bm else None
        if body is not None:
            inner_kinds = {o.kind for o in body.ops.values()}
            if inner_kinds & _SLICE_WRITES:
                # in-place slice-update fusion: traffic ~ the update slices
                upd = 0.0
                for o in body.ops.values():
                    if o.kind in _SLICE_WRITES:
                        shapes = _operand_shapes(body, o)
                        idx = 1 if o.kind == "dynamic-update-slice" else \
                            min(2, len(shapes) - 1)
                        if 0 <= idx < len(shapes):
                            upd += _shape_info(shapes[idx])[0]
                # plus any small non-aliased operands (capped at output)
                return 3.0 * upd if upd else float(out_b)
            if inner_kinds & _SLICE_READS:
                # slice-read fusion: output + the sliced reads (~output)
                return 3.0 * out_b
    total = float(out_b)
    for s in ops_shapes:
        total += _shape_info(s)[0]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    if "source_target_pairs" in line:
        return 2
    return 1


def _collective(op: OpInfo) -> Optional[Tuple[str, float, float]]:
    base = op.kind.replace("-start", "").replace("-done", "")
    if base not in _COLLECTIVES or op.kind.endswith("-done"):
        return None
    if op.kind.endswith("-start"):
        # async start tuple = (input, result [, ctx]); summing it double
        # counts the transfer — the result is the largest element.
        sizes = []
        for dt, dims in _SHAPE.findall(op.shape_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            sizes.append(n * _DTYPE_BYTES[dt])
        nbytes = max(sizes) if sizes else 0
    else:
        nbytes, _ = _shape_info(op.shape_str)
    if nbytes == 0:
        return None
    k = _group_size(op.line)
    if base == "all-reduce":
        traffic = 2.0 * (k - 1) / k * nbytes if k > 1 else 0.0
    elif base == "collective-permute":
        traffic = float(nbytes)
    else:
        traffic = (k - 1) / k * nbytes if k > 1 else 0.0
    return base, float(nbytes), traffic


def computation_multipliers(comps: Dict[str, Computation],
                            entry: str) -> Dict[str, float]:
    """Effective execution count of every computation, walking the call
    graph from ``entry`` and multiplying by enclosing ``known_trip_count``s
    (scan-over-layers / microbatch loops).  Shared with the collective
    auditor (``repro.analysis.collectives``), which needs per-op trip
    multipliers rather than aggregate costs."""
    mult: Dict[str, float] = {name: 0.0 for name in comps}

    def visit(name: str, m: float, depth: int = 0):
        if name not in comps or depth > 64:
            return
        mult[name] += m
        comp = comps[name]
        for op in comp.ops.values():
            if op.kind == "while":
                tm = _TRIP.search(op.line)
                trips = float(tm.group(1)) if tm else 1.0
                bm = _CALLS.search(op.line)
                if bm:
                    visit(bm.group(1), m * trips, depth + 1)
                cm = _COND.search(op.line)
                if cm:
                    visit(cm.group(1), m * trips, depth + 1)
            elif op.kind in ("fusion", "call", "custom-call",
                             "conditional"):
                bm = _CALLS.search(op.line)
                if bm:
                    visit(bm.group(1), m, depth + 1)

    visit(entry, 1.0)
    return mult


def analyze(text: str) -> CostSummary:
    comps, entry = parse_computations(text)
    if not entry:
        return CostSummary()
    mult = computation_multipliers(comps, entry)
    # computations reached as fusion bodies: their ops stream through
    # registers/VMEM — only the fusion op at the call site moves HBM bytes.
    fusion_bodies = set()
    for comp in comps.values():
        for op in comp.ops.values():
            if op.kind == "fusion":
                bm = _CALLS.search(op.line)
                if bm:
                    fusion_bodies.add(bm.group(1))
    out = CostSummary()
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        in_fusion = name in fusion_bodies
        for op in comp.ops.values():
            if op.kind == "dot":
                out.flops += m * _dot_flops(comp, op)
            if not in_fusion:
                out.bytes_accessed += m * _op_bytes(comp, op, comps)
            coll = _collective(op)
            if coll:
                kind, nbytes, traffic = coll
                out.collective_bytes += m * nbytes
                out.collective_traffic += m * traffic
                out.collective_by_kind[kind] = \
                    out.collective_by_kind.get(kind, 0.0) + m * traffic
    return out
