"""Production mesh definition for the multi-pod dry-run.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import and only then builds meshes.

Single pod:  (16, 16)    -> ("data", "model")     = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) -> ("pod", "data", "model") = 512 chips, the 'pod'
axis crossing DCN.  Batch shards over ('pod','data') by default; the
pipeline hillclimb maps PP onto 'pod' instead (paper H5: PP across the slow
domain, DP within).

Mesh construction lives in ``repro.dist.mesh`` (shared with the elastic
trainer and the MPMD pipeline); this module only pins the production
shapes.
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.dist import mesh as dist_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    if multi_pod:
        return dist_mesh.pod_data_model_mesh(2, 16, 16)
    return dist_mesh.data_model_mesh(16, 16)
