"""Production mesh definition for the multi-pod dry-run.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import and only then builds meshes.

Single pod:  (16, 16)    -> ("data", "model")     = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) -> ("pod", "data", "model") = 512 chips, the 'pod'
axis crossing DCN.  Batch shards over ('pod','data') by default; the
pipeline hillclimb maps PP onto 'pod' instead (paper H5: PP across the slow
domain, DP within).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
