"""End-to-end training driver.

Plans (Sailor planner against a cluster spec, or an explicit dp/tp), builds
the mesh over local devices, and trains with the elastic runtime —
checkpointing, straggler telemetry and kill-free reconfiguration included.

Examples:
  # ~100M-param model, a few hundred steps on CPU (reduced smoke: --reduced)
  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --reduced \
      --steps 200 --seq-len 128 --global-batch 8

  # plan first against a simulated cluster, then execute the plan's dp/tp
  PYTHONPATH=src python -m repro.launch.train --arch opt-350m --plan \
      --cluster a100:8 --steps 50 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax

from repro.configs import get_config
from repro.core.cluster import heterogeneous_zone
from repro.core.planner.objectives import MAX_THROUGHPUT, Objective
from repro.core.planner.search import plan_for
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib
from repro.train.elastic import ElasticTrainer, RuntimePlan


def parse_cluster(spec: str):
    """'a100:8,v100:16' -> heterogeneous single-zone ClusterSpec."""
    names = {"a100": "A100-40", "v100": "V100-16", "v5e": "tpu-v5e",
             "gh200": "GH200", "cpu": "cpu-host"}
    cap = {}
    for part in spec.split(","):
        t, n = part.split(":")
        cap[names.get(t.lower(), t)] = int(n)
    return heterogeneous_zone(cap)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--num-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--dp", type=int, default=0, help="0 = all devices")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--plan", action="store_true",
                    help="run the Sailor planner first and print its plan")
    ap.add_argument("--cluster", default="a100:8")
    ap.add_argument("--workdir", default="artifacts/train")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    if args.plan:
        cluster = parse_cluster(args.cluster)
        res = plan_for(cfg, cluster, Objective(MAX_THROUGHPUT),
                       seq_len=args.seq_len, global_batch=args.global_batch)
        if res.best is None:
            raise SystemExit("planner found no valid plan")
        print(f"[planner] search={res.search_time_s:.2f}s "
              f"t_iter={res.best.t_iter:.3f}s "
              f"cost=${res.best.cost_per_iter:.4f}/iter")
        print(res.best.plan.describe())

    n_dev = len(jax.devices())
    dp = args.dp or max(1, n_dev // args.tp)
    data_cfg = data_lib.DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        num_microbatches=args.num_micro)
    opt_cfg = opt_lib.OptimizerConfig(lr=args.lr, warmup_steps=10,
                                      total_steps=args.steps)
    trainer = ElasticTrainer(
        cfg, opt_cfg, data_cfg, workdir=args.workdir,
        checkpoint_every=args.checkpoint_every,
        plan_fn=lambda n: RuntimePlan(
            n_devices=dp * args.tp, dp=dp, tp=args.tp,
            num_microbatches=args.num_micro))
    trainer.build(dp * args.tp)
    t0 = time.time()
    log = trainer.train(args.steps)
    dt = time.time() - t0
    toks = args.steps * args.global_batch * args.seq_len
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s) loss {log[0]['loss']:.3f} -> "
          f"{log[-1]['loss']:.3f}")
    if trainer.detector.events:
        print(f"[train] straggler events at steps {trainer.detector.events}")


if __name__ == "__main__":
    main()
