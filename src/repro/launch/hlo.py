"""Post-SPMD HLO analysis: collective traffic extraction.

``collective_bytes`` parses the compiled (per-device) HLO text and sums the
result-shape bytes of every collective op, grouped by op kind.  Ring-scaled
traffic estimates feed the §Roofline collective term:

    all-reduce       2 (k-1)/k * bytes     (k = replica group size)
    all-gather       (k-1)/k * bytes       (bytes = gathered result)
    reduce-scatter   (k-1)/k * bytes(input ~ result*k)
    all-to-all       (k-1)/k * bytes
    collective-permute  bytes              (one hop)

Group sizes come from ``replica_groups=[G,S]<=...`` annotations (S = group
size); old-style explicit lists ``{{0,1},{2,3}}`` are also handled.

Split-phase (async) collectives appear as a ``-start`` / ``-done`` pair;
only the ``-start`` (or bare, synchronous) form is counted.  A ``-start``
op's shape is a tuple carrying BOTH the aliased input and the result
buffer (``(f32[128], f32[512]) all-gather-start(...)``), so tuple shapes
on start ops contribute their largest element only — summing the tuple
double-counts the transfer (result == k * input for all-gather, input ==
result for the rest, so the max is the result).  Bare variadic collectives
(``(f32[a], f32[b]) all-reduce(x, y)``) reduce distinct buffers and DO sum.

Shapes whose dtype is not in the catalog are not silently dropped: the
dtype token is surfaced in ``CollectiveStats.unknown_dtypes`` so the
static auditor (``repro.analysis``) can emit a warning finding instead of
under-reporting traffic.

``repro.analysis.collectives`` builds a structured per-op IR (replica
groups resolved to device ids, trip-count multipliers) on top of the same
grammar; this module stays the cheap aggregate used by the dry-run.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[\w\[\],\s{}]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elements(shape_str: str) -> Tuple[List[int], List[str]]:
    """Per-tuple-element byte sizes of a (possibly tuple) shape string,
    plus any dtype tokens missing from the catalog."""
    sizes: List[int] = []
    unknown: List[str] = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            unknown.append(dt)
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes.append(n * _DTYPE_BYTES[dt])
    return sizes, unknown


def result_bytes(shape_str: str, phase: Optional[str]) -> Tuple[int, List[str]]:
    """Transferred bytes of one collective given its async phase.

    ``phase`` is ``"-start"`` / ``"-done"`` / None (bare).  Start-op tuples
    carry (input, result) — take the max element; bare tuples are variadic
    results — sum them.
    """
    sizes, unknown = shape_elements(shape_str)
    if not sizes:
        return 0, unknown
    if phase == "-start" and len(sizes) > 1:
        return max(sizes), unknown
    return sum(sizes), unknown


@dataclasses.dataclass
class CollectiveStats:
    # op kind -> (count, raw result bytes, ring-scaled traffic bytes)
    by_kind: Dict[str, Tuple[int, int, float]]
    # dtype tokens seen on collective shapes but missing from the catalog
    # (their bytes are NOT in by_kind — the auditor warns on these)
    unknown_dtypes: Tuple[str, ...] = ()

    @property
    def total_bytes(self) -> int:
        return sum(v[1] for v in self.by_kind.values())

    @property
    def total_traffic(self) -> float:
        return sum(v[2] for v in self.by_kind.values())


_COMMENT = re.compile(r"/\*.*?\*/")


def ring_traffic(kind: str, nbytes: float, k: int) -> float:
    """Ring-scaled wire traffic of one collective (matches network.py)."""
    if kind == "collective-permute":
        return float(nbytes)
    if k <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (k - 1) / k * nbytes
    return (k - 1) / k * nbytes


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind: Dict[str, List[float]] = {}
    unknown: List[str] = []
    for line in hlo_text.splitlines():
        line = _COMMENT.sub("", line)
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue                    # count -start (or bare) forms only
        nbytes, unk = result_bytes(shape_str, phase)
        for dt in unk:
            if dt not in unknown:
                unknown.append(dt)
        if nbytes == 0:
            continue
        k = group_size(line)
        traffic = ring_traffic(kind, nbytes, k)
        cur = by_kind.setdefault(kind, [0, 0, 0.0])
        cur[0] += 1
        cur[1] += nbytes
        cur[2] += traffic
    return CollectiveStats(
        by_kind={k: (int(v[0]), int(v[1]), float(v[2]))
                 for k, v in by_kind.items()},
        unknown_dtypes=tuple(unknown))


def group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    if _SRC_TGT_RE.search(line):
        return 2
    return 1


_group_size = group_size        # backward-compatible private alias
