"""Post-SPMD HLO analysis: collective traffic extraction.

``collective_bytes`` parses the compiled (per-device) HLO text and sums the
result-shape bytes of every collective op, grouped by op kind.  Ring-scaled
traffic estimates feed the §Roofline collective term:

    all-reduce       2 (k-1)/k * bytes     (k = replica group size)
    all-gather       (k-1)/k * bytes       (bytes = gathered result)
    reduce-scatter   (k-1)/k * bytes(input ~ result*k)
    all-to-all       (k-1)/k * bytes
    collective-permute  bytes              (one hop)

Group sizes come from ``replica_groups=[G,S]<=...`` annotations (S = group
size); old-style explicit lists ``{{0,1},{2,3}}`` are also handled.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[\w\[\],\s{}]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    # op kind -> (count, raw result bytes, ring-scaled traffic bytes)
    by_kind: Dict[str, Tuple[int, int, float]]

    @property
    def total_bytes(self) -> int:
        return sum(v[1] for v in self.by_kind.values())

    @property
    def total_traffic(self) -> float:
        return sum(v[2] for v in self.by_kind.values())


_COMMENT = re.compile(r"/\*.*?\*/")


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind: Dict[str, List[float]] = {}
    for line in hlo_text.splitlines():
        line = _COMMENT.sub("", line)
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue                    # avoid double count of start/done
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        if nbytes == 0:
            continue
        k = _group_size(line)
        if kind == "all-reduce":
            traffic = 2.0 * (k - 1) / k * nbytes if k > 1 else 0.0
        elif kind == "collective-permute":
            traffic = float(nbytes)
        else:
            traffic = (k - 1) / k * nbytes if k > 1 else 0.0
        cur = by_kind.setdefault(kind, [0, 0, 0.0])
        cur[0] += 1
        cur[1] += nbytes
        cur[2] += traffic
    return CollectiveStats(
        by_kind={k: (int(v[0]), int(v[1]), float(v[2]))
                 for k, v in by_kind.items()})


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    if _SRC_TGT_RE.search(line):
        return 2
    return 1
