"""Input stand-ins (ShapeDtypeStruct) per (arch x shape) cell.

No device allocation: everything is abstract shapes + shardings, the same
pattern the dry-run uses to prove a configuration compiles and fits.

Applicability rules (assignment):
  * long_500k needs sub-quadratic attention -> run only for ssm/hybrid/SWA
    archs; full-attention archs return a skip marker (noted in DESIGN.md).
  * encoder-only archs would skip decode; all ten assigned archs have a
    decoder, so decode shapes always apply here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeConfig, get_shape
from repro.serve import serve_step


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    kind: str                       # train | prefill | decode
    args: Tuple                     # ShapeDtypeStructs for the step fn
    num_microbatches: int = 1
    skip_reason: Optional[str] = None


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is full-attention (skip per assignment)")
    return None


def num_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                     mesh: Mesh) -> int:
    if shape.kind != "train":
        return 1
    if shape.num_microbatches:
        return shape.num_microbatches
    dp = int(np.prod([mesh.shape[a] for a in shd.dp_axes(mesh)]))
    # keep per-shard microbatch tokens ~<= 8k so remat'd activations of the
    # widest archs stay inside 16 GB (see DESIGN.md §9)
    per_shard = shape.global_batch // max(dp, 1)
    target_seqs = max(1, 8192 // shape.seq_len)
    nm = 1
    while (per_shard // nm) > target_seqs and nm < 8:
        nm *= 2
    while shape.global_batch % (nm * dp) != 0 and nm > 1:
        nm //= 2
    return nm


def _sds(shape, dtype, mesh: Mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def param_sds(cfg: ModelConfig, mesh: Mesh):
    decls = model_lib.decls(cfg)
    specs = shd.param_specs(decls, cfg.sharding, mesh)
    return jax.tree_util.tree_map(
        lambda d, s: _sds(d.shape, cfg.param_dtype, mesh, s),
        decls, specs, is_leaf=lambda x: isinstance(x, shd.Decl))


def opt_sds(cfg: ModelConfig, mesh: Mesh):
    p = param_sds(cfg, mesh)
    moments = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                       sharding=s.sharding), p)
    return {"m": moments, "v": moments,
            "step": _sds((), jnp.int32, mesh, P())}


def batch_sds(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              nm: int) -> Dict[str, jax.ShapeDtypeStruct]:
    mb = shape.global_batch // nm
    dp = shd.batch_spec(mesh, mb)[0]
    s = shape.seq_len
    n_text = s - cfg.n_patches if cfg.family == "vlm" else s
    out = {
        "tokens": _sds((nm, mb, n_text), jnp.int32, mesh, P(None, dp, None)),
        "labels": _sds((nm, mb, s), jnp.int32, mesh, P(None, dp, None)),
    }
    if cfg.family == "encdec":
        out["frames"] = _sds((nm, mb, cfg.n_frames, cfg.d_model),
                             jnp.bfloat16, mesh, P(None, dp, None, None))
    if cfg.family == "vlm":
        out["patches"] = _sds((nm, mb, cfg.n_patches, cfg.d_model),
                              jnp.bfloat16, mesh, P(None, dp, None, None))
    return out


def infer_batch_sds(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Prefill inputs: (B, S) without the microbatch dim."""
    b = shape.global_batch
    dp = shd.batch_spec(mesh, b)[0]
    s = shape.seq_len
    n_text = s - cfg.n_patches if cfg.family == "vlm" else s
    out = {"tokens": _sds((b, n_text), jnp.int32, mesh, P(dp, None))}
    if cfg.family == "encdec":
        out["frames"] = _sds((b, cfg.n_frames, cfg.d_model), jnp.bfloat16,
                             mesh, P(dp, None, None))
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), jnp.bfloat16,
                              mesh, P(dp, None, None))
    return out


def cache_sds(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    decls = model_lib.cache_decls(cfg, shape.global_batch, shape.seq_len)
    specs = serve_step.cache_specs(cfg, shape.global_batch, shape.seq_len,
                                   mesh)
    def mk(d: shd.Decl, s: P):
        dt = jnp.int32 if d.shape == () else jnp.bfloat16
        if "ssm" in str(d.axes) and len(d.shape) == 5:
            dt = jnp.float32               # ssm states kept fp32
        return _sds(d.shape, dt, mesh, s)
    return jax.tree_util.tree_map(
        mk, decls, specs, is_leaf=lambda x: isinstance(x, shd.Decl))


def build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh,
               nm_override: int = 0) -> Cell:
    shape = get_shape(shape_name)
    if nm_override:
        shape = dataclasses.replace(shape, num_microbatches=nm_override)
    skip = applicable(cfg, shape)
    if skip:
        return Cell(cfg, shape, shape.kind, (), skip_reason=skip)
    if shape.kind == "train":
        nm = num_microbatches(cfg, shape, mesh)
        args = (param_sds(cfg, mesh), opt_sds(cfg, mesh),
                batch_sds(cfg, shape, mesh, nm))
        return Cell(cfg, shape, "train", args, num_microbatches=nm)
    if shape.kind == "prefill":
        args = (param_sds(cfg, mesh), infer_batch_sds(cfg, shape, mesh))
        return Cell(cfg, shape, "prefill", args)
    # decode: one new token against a seq_len cache
    b = shape.global_batch
    dp = shd.batch_spec(mesh, b)[0]
    tokens = _sds((b, 1), jnp.int32, mesh, P(dp, None))
    args = (param_sds(cfg, mesh), cache_sds(cfg, shape, mesh), tokens)
    return Cell(cfg, shape, "decode", args)
