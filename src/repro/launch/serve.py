"""Serving driver: batched greedy decoding against a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_0_5b --reduced \
      --requests 16 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serve.serve_step import BatchedServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    server = BatchedServer(cfg, params,
                           max_len=args.prompt_len + args.max_new + 8,
                           batch_size=args.batch_size)
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    assert all(r.done for r in reqs)
    print("sample output:", reqs[0].output[:8])


if __name__ == "__main__":
    main()
