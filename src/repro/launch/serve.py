"""Serving driver: batched greedy decoding against a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_0_5b --reduced \
      --requests 16 --prompt-len 32 --max-new 16

A warmup batch runs (and times) jit compilation of the prefill + decode
programs separately, so the reported tok/s is steady-state throughput —
the old single timer lumped XLA compile time into the serving window and
underreported throughput by an order of magnitude on short runs.
``--continuous`` serves through the paged continuous-batching scheduler
instead of the static lockstep batch.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serve.serve_step import BatchedServer, Request


def make_requests(cfg, n: int, prompt_len: int, max_new: int,
                  seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--continuous", action="store_true",
                    help="serve via the paged continuous-batching scheduler")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.max_new + 8

    def build_server():
        if args.continuous:
            from repro.serve.scheduler import ContinuousBatchingServer
            return ContinuousBatchingServer(cfg, params,
                                            max_slots=args.batch_size,
                                            max_ctx=max_len)
        return BatchedServer(cfg, params, max_len=max_len,
                             batch_size=args.batch_size)

    server = build_server()
    # warmup: one full batch through prefill + decode compiles every shape
    # the timed run will hit; time it separately.
    warm = make_requests(cfg, args.batch_size, args.prompt_len,
                         args.max_new, seed=1)
    t0 = time.time()
    server.run(warm)
    t_compile = time.time() - t0

    reqs = make_requests(cfg, args.requests, args.prompt_len, args.max_new)
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in reqs)
    mode = "continuous" if args.continuous else "static"
    print(f"[serve:{mode}] compile+warmup {t_compile:.2f}s")
    print(f"[serve:{mode}] {len(reqs)} requests, {n_tok} tokens in "
          f"{dt:.2f}s steady-state ({n_tok / dt:.1f} tok/s)")
    assert all(r.done for r in reqs)
    print("sample output:", reqs[0].output[:8])


if __name__ == "__main__":
    main()
