"""Serving steps: jitted prefill + decode, and a batched request server.

``prefill_step`` runs the full-sequence forward returning (last-token
logits, cache); ``decode_step`` advances one token for the whole batch.
Cache shardings come from the same logical-axis rules as parameters
(``kv_heads -> model`` where divisible, else the long sequence dim — see
dist/sharding.py and the dry-run notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serve import kv_cache

# logical rules for cache tensors: prefer kv-head sharding, fall back to
# sequence (context-parallel decode), never both on 'model'.
CACHE_RULES = {
    "kv_heads": ("model",),
    "kv_seq": ("model",),
    "ssm_inner": ("model",),
}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh):
    decls = model_lib.cache_decls(cfg, batch, max_len)

    def to_spec(d: shd.Decl):
        # try kv_heads first; if it didn't shard, allow kv_seq
        spec = shd.logical_to_spec(d.shape, d.axes,
                                   {"kv_heads": ("model",),
                                    "ssm_inner": ("model",)}, mesh)
        if all(s is None for s in spec) and "kv_seq" in d.axes:
            spec = shd.logical_to_spec(d.shape, d.axes,
                                       {"kv_seq": ("model",)}, mesh)
        # batch dim (index of None-axis dim 1) handled via dp below
        return spec

    specs = jax.tree_util.tree_map(to_spec, decls,
                                   is_leaf=lambda x: isinstance(x, shd.Decl))
    # shard batch dim (dim 1 for stacked caches) over dp axes when divisible
    dp = shd.batch_spec(mesh, batch)[0]

    def add_dp(d: shd.Decl, spec: P):
        parts = list(spec)
        for i, ax in enumerate(d.axes):
            if ax is None and i == 1 and d.shape[i] == batch and dp is not None:
                if parts[i] is None:
                    parts[i] = dp
        return P(*parts)

    return jax.tree_util.tree_map(add_dp, decls, specs,
                                  is_leaf=lambda x: isinstance(x, shd.Decl))


def make_prefill(cfg: ModelConfig, mesh: Optional[Mesh] = None) -> Callable:
    def prefill(params, batch):
        logits, cache = model_lib.forward(cfg, params, batch, mesh=mesh,
                                          return_cache=True)
        return logits[:, -1], cache
    return prefill


def make_decode(cfg: ModelConfig, mesh: Optional[Mesh] = None) -> Callable:
    def decode(params, cache, tokens):
        logits, cache = model_lib.decode(cfg, params, cache, tokens,
                                         mesh=mesh)
        return logits[:, -1], cache
    return decode


# --- a small batched-requests server (greedy sampling) ---------------------------


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new_tokens: int
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Static-batch server: pads a batch of requests, prefills once, then
    decodes in lockstep until every request finishes (used by
    examples/serve_batched.py and the serve smoke tests).

    Finished rows are compacted out: once live requests fall to half the
    current batch, the cache/batch are gathered down to the live rows, so
    a batch with mixed ``max_new_tokens`` stops paying full-batch decode
    steps for dead rows.  Halving bounds recompiles at log2(batch) while
    capping wasted row-steps at 2x the useful work.  ``decode_steps`` /
    ``decode_row_steps`` count the actual work for the regression test.
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 batch_size: int = 8):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self._prefill = jax.jit(make_prefill(cfg))
        self._decode = jax.jit(make_decode(cfg))
        self.decode_steps = 0        # decode_step launches
        self.decode_row_steps = 0    # sum of batch rows over launches

    def run(self, requests: List[Request]) -> List[Request]:
        for i in range(0, len(requests), self.batch_size):
            self._run_batch(requests[i:i + self.batch_size])
        return requests

    def _run_batch(self, reqs: List[Request]):
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, self.cfg.n_frames, self.cfg.d_model), jnp.float32)
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (b, self.cfg.n_patches, self.cfg.d_model), jnp.float32)
        logits, cache = self._prefill(self.params, batch)
        # re-home the cache into a max_len buffer
        full = model_lib.init_cache(self.cfg, b, self.max_len)
        cache = kv_cache.grow_cache(cache, full)
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        rows = list(range(b))        # batch row -> index into reqs
        while True:
            for j, ri in enumerate(rows):
                r = reqs[ri]
                if not r.done:
                    r.output.append(int(cur[j, 0]))
                    if len(r.output) >= r.max_new_tokens:
                        r.done = True
            live = [j for j, ri in enumerate(rows) if not reqs[ri].done]
            if not live:
                break
            if len(live) <= len(rows) // 2:
                # gather the cache down to the live rows (rows decode
                # independently, so trajectories are unchanged)
                nrows = len(rows)
                idx = jnp.asarray(live)

                def take(v):
                    if getattr(v, "ndim", 0) == 0:
                        return v
                    if v.ndim >= 2 and v.shape[1] == nrows:
                        return v[:, idx]
                    if v.shape[0] == nrows:
                        return v[idx]
                    return v
                cache = {k: take(v) for k, v in cache.items()}
                cur = cur[idx]
                rows = [rows[j] for j in live]
            logits, cache = self._decode(self.params, cache, cur)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            self.decode_steps += 1
            self.decode_row_steps += len(rows)



