"""Continuous-batching scheduler over a paged KV cache.

The static ``BatchedServer`` admits one batch, decodes it to completion,
and only then starts the next — short requests finish early and their
slots idle while stragglers drain.  This server admits and retires
requests at every decode-step boundary:

* **Slots.**  A fixed pool of ``max_slots`` cache rows.  Live requests
  always occupy the row prefix ``[0, n_live)`` (finish/preempt swaps the
  last live row down), so a decode step runs on a *prefix slice* of the
  cache at the next power-of-2 above ``n_live`` — shape-stable for at
  most log2(max_slots) compiled batch sizes, with dead rows bounded by
  half the sliced batch.
* **Pages.**  Admission and per-token growth go through the same
  ``PagedKVAllocator`` the serving simulator uses: a request is admitted
  only when a slot AND its prompt's pages are free; growth that finds the
  pool exhausted preempts the most recently admitted request back to the
  queue (recompute-style, vLLM semantics).
* **Per-row positions.**  The cache's ``len`` is a (B,) vector — rows
  admitted at different times decode together, each masking its own
  context (``models/transformer.decode`` per-row path).

Transformer families only (dense/moe): continuous batching needs the
per-row decode path; SSM/hybrid state caches decode lockstep via
``BatchedServer``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serve.paged_cache import PagedKVAllocator
from repro.serve.serve_step import Request, make_decode, make_prefill


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class ServerStats:
    decode_steps: int = 0        # decode_step launches
    decode_row_steps: int = 0    # sum of sliced batch sizes over launches
    prefill_calls: int = 0
    n_preempted: int = 0
    n_finished: int = 0
    peak_pages: int = 0


class ContinuousBatchingServer:
    """Admit/evict by page budget; decode a dead-slot-free prefix batch."""

    def __init__(self, cfg: ModelConfig, params, max_slots: int = 8,
                 max_ctx: int = 512, page_size: int = 16,
                 total_pages: Optional[int] = None):
        assert cfg.family in ("dense", "moe"), \
            "continuous batching needs the per-row transformer decode path"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_ctx = max_ctx
        self.page_size = page_size
        if total_pages is None:
            total_pages = max_slots * (-(-max_ctx // page_size))
        self.alloc = PagedKVAllocator(total_pages, page_size)
        self._prefill = jax.jit(make_prefill(cfg))
        self._decode = make_decode(cfg)
        self._step_fns: Dict[int, object] = {}   # pow2 bsz -> jitted step
        cache = model_lib.init_cache(cfg, max_slots, max_ctx)
        self.cache: Dict[str, jax.Array] = dict(cache)
        # per-row positions; idle rows sit at 1 (a 0 would mask every
        # position and NaN the softmax — their logits are discarded)
        self.len_np = np.ones((max_slots,), np.int32)
        self.cur = np.zeros((max_slots, 1), np.int32)
        # device mirror of (len, cur): valid between event-free decode
        # steps so steady-state decoding uploads nothing; any host-side
        # mutation (admit/finish/preempt) drops it
        self._dev_state = None
        self.queue: List[Request] = []
        self.live: List[Request] = []       # row i <-> live[i]
        self.stats = ServerStats()

    # --- queue/slot management ------------------------------------------------
    def submit(self, req: Request) -> None:
        assert len(req.prompt) + req.max_new_tokens <= self.max_ctx, \
            "request exceeds the context budget"
        self.queue.append(req)

    def _write_row(self, row: int, pcache: Dict[str, jax.Array],
                   bucket: int) -> None:
        for key in ("k", "v"):
            v = pcache[key][:, 0]            # (layers, bucket, kv, hd)
            self.cache[key] = jax.lax.dynamic_update_slice(
                self.cache[key], v[:, None].astype(self.cache[key].dtype),
                (0, row, 0, 0, 0))
        self.len_np[row] = bucket
        self._dev_state = None

    def _remove_row(self, row: int) -> None:
        """Swap the last live row into ``row`` (prefix compaction)."""
        self._dev_state = None
        last = len(self.live) - 1
        if row != last:
            for key in ("k", "v"):
                self.cache[key] = self.cache[key].at[:, row].set(
                    self.cache[key][:, last])
            self.len_np[row] = self.len_np[last]
            self.cur[row] = self.cur[last]
            self.live[row] = self.live[last]
        self.live.pop()
        self.len_np[last] = 1
        self.cur[last] = 0

    def _admit(self) -> None:
        while self.queue and len(self.live) < self.max_slots:
            req = self.queue[0]
            plen = len(req.prompt)
            if not self.alloc.alloc(req.rid, plen):
                break                        # pages exhausted: wait
            self.queue.pop(0)
            # bucket the prompt to a power of 2 (left-pad): bounded
            # prefill compile shapes
            bucket = min(_next_pow2(plen), self.max_ctx)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, bucket - plen:] = req.prompt
            logits, pcache = self._prefill(self.params,
                                           {"tokens": jnp.asarray(toks)})
            self.stats.prefill_calls += 1
            row = len(self.live)
            self.live.append(req)
            self._write_row(row, pcache, bucket)
            first = int(jnp.argmax(logits[0], axis=-1))
            self.cur[row, 0] = first
            req.output.append(first)
            if len(req.output) >= req.max_new_tokens:
                self._finish(row)
        self.stats.peak_pages = max(self.stats.peak_pages,
                                    self.alloc.used_pages)

    def _finish(self, row: int) -> None:
        req = self.live[row]
        req.done = True
        self.alloc.release(req.rid)
        self.stats.n_finished += 1
        self._remove_row(row)

    def _preempt_latest(self) -> bool:
        """Evict the most recently admitted request (recompute on
        re-admission).  False if there is nothing to evict."""
        if len(self.live) <= 1:
            return False
        row = len(self.live) - 1
        req = self.live[row]
        self.alloc.release(req.rid)
        req.output.clear()
        self._remove_row(row)
        self.queue.insert(0, req)
        self.stats.n_preempted += 1
        return True

    def _step_fn(self, bsz: int):
        """One fused program per pow2 batch size: prefix-slice the cache,
        decode, scatter the new row back, greedy-pick — a single dispatch
        per decode step instead of slice/decode/update/argmax launches
        (the unfused chain ate the scheduling win on small models)."""
        fn = self._step_fns.get(bsz)
        if fn is None:
            decode = self._decode

            def f(params, k, v, lens, cur):
                cache = {"k": k[:, :bsz], "v": v[:, :bsz], "len": lens[:bsz]}
                logits, new = decode(params, cache, cur[:bsz])
                k = jax.lax.dynamic_update_slice(
                    k, new["k"].astype(k.dtype), (0, 0, 0, 0, 0))
                v = jax.lax.dynamic_update_slice(
                    v, new["v"].astype(v.dtype), (0, 0, 0, 0, 0))
                nxt = jnp.argmax(logits, axis=-1)
                # advance the mirrored prefix too (idle rows in the slice
                # drift, but their logits are discarded and any admission
                # resets the mirror from the host arrays)
                lens = jax.lax.dynamic_update_slice(lens, lens[:bsz] + 1,
                                                    (0,))
                cur = jax.lax.dynamic_update_slice(
                    cur, nxt[:, None].astype(cur.dtype), (0, 0))
                return k, v, nxt, lens, cur
            fn = jax.jit(f)
            self._step_fns[bsz] = fn
        return fn

    # --- the step -------------------------------------------------------------
    def step(self) -> bool:
        """Admissions, then ONE decode step over the live prefix.
        Returns False when queue and slots are both empty."""
        self._admit()
        if not self.live:
            if self.queue:
                raise RuntimeError(
                    "head-of-line request cannot fit the page budget")
            return False
        # grow page allocations for the token this step will append
        row = 0
        while row < len(self.live):
            req = self.live[row]
            if self.alloc.extend(req.rid, int(self.len_np[row]) + 1):
                row += 1
                continue
            if not self._preempt_latest() or row >= len(self.live):
                row += 1                     # at capacity: decode anyway
        n_live = len(self.live)
        bsz = min(_next_pow2(n_live), self.max_slots)
        if self._dev_state is None:
            lens_d, cur_d = jnp.asarray(self.len_np), jnp.asarray(self.cur)
        else:
            lens_d, cur_d = self._dev_state
        k, v, nxt, lens_d, cur_d = self._step_fn(bsz)(
            self.params, self.cache["k"], self.cache["v"], lens_d, cur_d)
        self.cache["k"], self.cache["v"] = k, v
        self._dev_state = (lens_d, cur_d)
        self.stats.decode_steps += 1
        self.stats.decode_row_steps += bsz
        nxt = np.asarray(nxt, np.int32)
        self.len_np[:n_live] += 1
        done: List[Request] = []
        for r_i in range(n_live):
            req = self.live[r_i]
            req.output.append(int(nxt[r_i]))
            self.cur[r_i, 0] = nxt[r_i]
            if len(req.output) >= req.max_new_tokens:
                done.append(req)
        for req in done:                     # finish by identity: each
            self._finish(self.live.index(req))   # _finish swaps rows
        return bool(self.live or self.queue)

    def run(self, requests: List[Request]) -> List[Request]:
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return requests
