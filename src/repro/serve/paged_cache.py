"""Paged KV-cache block allocator (vLLM-style page accounting).

The cache of a serving replica is carved into fixed-size pages of
``page_size`` tokens; a sequence at ``ctx`` live tokens holds
``ceil(ctx / page_size)`` pages.  This module is the *accounting* layer:
pure Python, no jax — so the serving simulator (``core/simulator/serving``)
and the real continuous-batching server (``serve/scheduler``) share the
exact same admit/evict arithmetic and cannot drift.

The physical cache on the real server stays a dense ``(B, max_ctx, ...)``
buffer per slot (XLA wants static shapes); paging governs *admission* —
how many sequences may be resident at once given the HBM page budget —
not the layout.  That is the part that matters for feasibility and is what
``stage_peak_bytes`` gates on.
"""
from __future__ import annotations

from typing import Dict, Optional


class PagedKVAllocator:
    """Fixed pool of KV pages with per-sequence accounting."""

    def __init__(self, total_pages: int, page_size: int):
        assert total_pages >= 0 and page_size >= 1
        self.total_pages = int(total_pages)
        self.page_size = int(page_size)
        self._held: Dict[object, int] = {}   # seq id -> pages held
        self.peak_used = 0

    # --- queries -------------------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` of context (at least one)."""
        return max(-(-int(n_tokens) // self.page_size), 1)

    @property
    def used_pages(self) -> int:
        return sum(self._held.values())

    @property
    def free_pages(self) -> int:
        return self.total_pages - self.used_pages

    def pages_of(self, rid) -> int:
        return self._held.get(rid, 0)

    def can_fit(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= self.free_pages

    # --- mutation ------------------------------------------------------------
    def alloc(self, rid, n_tokens: int) -> bool:
        """Admit sequence ``rid`` with ``n_tokens`` of prefilled context.
        False (and no change) if the pool cannot cover it."""
        assert rid not in self._held, f"{rid!r} already resident"
        need = self.pages_needed(n_tokens)
        if need > self.free_pages:
            return False
        self._held[rid] = need
        self.peak_used = max(self.peak_used, self.used_pages)
        return True

    def extend(self, rid, n_tokens: int) -> bool:
        """Grow ``rid``'s allocation to cover ``n_tokens`` total context.
        False (and no change) if the extra pages are not available —
        caller must evict someone and retry."""
        held = self._held[rid]
        need = self.pages_needed(n_tokens)
        if need <= held:
            return True
        if need - held > self.free_pages:
            return False
        self._held[rid] = need
        self.peak_used = max(self.peak_used, self.used_pages)
        return True

    def release(self, rid) -> int:
        """Free all pages of ``rid`` (finish or preemption)."""
        return self._held.pop(rid, 0)


def page_bytes(cfg, page_size: int) -> int:
    """HBM bytes of ONE page of ONE sequence, from the model's own cache
    declarations (attention K/V for ``page_size`` tokens; SSM/conv state
    is constant per sequence and rides the first page)."""
    from repro.core.simulator.memory import kv_cache_bytes
    return kv_cache_bytes(cfg, batch=1, ctx=page_size, page_size=page_size)


def replica_page_budget(cfg, kv_budget_bytes: float,
                        page_size: int) -> int:
    """Pages a replica can hold given ``kv_budget_bytes`` of HBM headroom
    (usable memory minus the params + working-set peak)."""
    pb = page_bytes(cfg, page_size)
    if pb <= 0 or kv_budget_bytes <= 0:
        return 0
    return int(kv_budget_bytes // pb)


def kv_headroom_bytes(profile, layer_lo: int, layer_hi: int, batch: int,
                      tp: int, gpu_type: str, mem_cfg=None) -> float:
    """Unsharded KV bytes that fit on one replica: invert the affine
    ``serving_stage_peak_bytes`` in its ``kv_bytes`` argument against
    usable HBM.  Shared by the simulator's page-budget derivation and the
    planner's replica sizing."""
    from repro.core.profiler.hw_specs import get_accelerator
    from repro.core.simulator import memory as mem
    if mem_cfg is None:
        mem_cfg = mem.serving_mem_cfg()
    usable = get_accelerator(gpu_type).usable_mem_bytes
    base = mem.serving_stage_peak_bytes(profile, layer_lo, layer_hi,
                                        batch, tp, 0.0, mem_cfg)
    if base >= usable:
        return 0.0
    # peak(kv) = base + kv/tp * fragmentation  (kv rides the static stream)
    return (usable - base) * tp / mem_cfg.fragmentation
