"""KV-cache utilities: growth/re-homing and ring-buffer semantics.

Cache *layouts* are declared by each model family (``model.cache_decls``):
stacked-over-layers (L, B, S, K, hd) tensors for attention archs, constant
(L, B, H, P, N) states for SSM archs, ring buffers capped at the window for
SWA archs.  This module hosts the layout-agnostic operations the server
needs.
"""
from __future__ import annotations

from typing import Dict

import jax


def grow_cache(cache: Dict[str, jax.Array],
               full: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Re-home a prefill-sized cache into a larger decode buffer.

    Copies every tensor of ``cache`` into the leading slots of the
    corresponding (bigger) tensor in ``full``; scalars (``len``) pass
    through.  Ring caches (SWA) are size-preserving and pass through
    unchanged."""
    out = {}
    for k, dst in full.items():
        src = cache[k]
        if k == "len" or src.ndim == 0:
            out[k] = cache[k]
            continue
        if src.shape == dst.shape:
            out[k] = src.astype(dst.dtype)
            continue
        sl = tuple(slice(0, d) for d in src.shape)
        out[k] = dst.at[sl].set(src.astype(dst.dtype))
    return out


def cache_bytes(cache: Dict[str, jax.Array]) -> int:
    """Total bytes held by a cache pytree (tests: SSM decode is O(1)).

    Metadata-only: ``nbytes`` comes from shape x dtype, so the serving
    path never pays a device->host copy of the whole KV cache just to
    report its size (the old ``jax.device_get`` round-trip)."""
    return sum(int(v.nbytes) for v in jax.tree_util.tree_leaves(cache))
