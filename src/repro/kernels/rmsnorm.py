"""Fused RMSNorm Pallas kernel.

One pass over each (rows x d) tile: mean-of-squares reduction (VPU),
rsqrt, scale — fused so x is read from HBM exactly once (XLA emits a
separate reduce + multiply without fusion guarantees across the rsqrt).
Rows tile = 256, d kept whole (d <= ~8k fits VMEM at 4 bytes: 8 MB tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = ((x * jax.lax.rsqrt(var + eps))
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n_blocks = xf.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
