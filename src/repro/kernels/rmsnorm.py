"""Fused RMSNorm Pallas kernel.

One pass over each (rows x d) tile: mean-of-squares reduction (VPU),
rsqrt, scale — fused so x is read from HBM exactly once (XLA emits a
separate reduce + multiply without fusion guarantees across the rsqrt).
Rows tile defaults to 256 (autotunable), d kept whole (d <= ~8k fits VMEM
at 4 bytes: 8 MB tile).

Two refinements over the naive tiling:

  * ``scale`` is staged into VMEM scratch on the first grid step and read
    from there afterwards — the constant-index ``(d,)`` BlockSpec would
    otherwise re-fetch (and re-cast) it every step of the row sweep.
  * ragged row counts never compute dead tiles: the row range is split
    into a full-block sweep plus one exact-remainder call, instead of
    zero-padding the tail up to ``block_rows`` and normalizing garbage.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                         # pragma: no cover
    _VMEM = None


def _kernel(x_ref, s_ref, o_ref, scale_ref, *, eps: float):
    @pl.when(pl.program_id(0) == 0)
    def _hoist():                          # cast + stage scale once
        scale_ref[...] = s_ref[...].astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = ((x * jax.lax.rsqrt(var + eps))
                  * scale_ref[...]).astype(o_ref.dtype)


def _norm_rows(xf: jax.Array, scale: jax.Array, eps: float, br: int,
               interpret: bool) -> jax.Array:
    """xf: (rows, d) with rows % br == 0 — no padded tiles."""
    rows, d = xf.shape
    scratch = ([_VMEM((d,), jnp.float32)] if _VMEM is not None
               else [pl.MemorySpace.ANY])  # pragma: no cover (non-TPU)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, xf.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(xf, scale)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    br = min(block_rows, rows)
    full = rows - rows % br
    parts: List[jax.Array] = []
    if full:
        parts.append(_norm_rows(xf[:full], scale, eps, br, interpret))
    if rows - full:
        parts.append(_norm_rows(xf[full:], scale, eps, rows - full,
                                interpret))
    out = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return out.reshape(orig_shape)
