"""Flash attention Pallas kernels (TPU target, interpret-validated on CPU).

Blockwise online-softmax attention (Flash-Attention-2 recurrence) tiled for
the TPU memory hierarchy:

  * grid = (batch*heads, q_blocks, kv_blocks); the kv dimension is minor
    (sequential on a TensorCore), so the fp32 accumulators for one q block
    live in VMEM scratch across the kv sweep.
  * BlockSpecs stage (block_q x head_dim) / (block_k x head_dim) tiles of
    Q/K/V from HBM into VMEM; head_dim (64/80/128 here) stays unsplit so
    the MXU sees full contraction dims; block sizes default to 128 —
    MXU-aligned (128x128 systolic array) — and are overridable per shape
    by the autotuner (``kernels/autotune.py``).
  * causal masking is done with iota comparisons inside the block; blocks
    entirely above the diagonal are skipped via ``pl.when`` (the FLOP
    saving XLA's dense attention cannot express).
  * non-divisible ``sq``/``sk`` are handled by internal zero-padding to
    the block grid plus an in-kernel ``k_pos >= kv_len`` mask (padded KV
    columns contribute nothing; padded Q rows are sliced off).  Blocks
    entirely past ``kv_len`` are skipped like above-diagonal ones.

The training kernel computes one (q_block, head) tile per grid step:
    m_new = max(m, rowmax(S));  l = l*corr + rowsum(P);  acc = acc*corr + P V
with S = Q K^T / sqrt(d) in fp32.

``flash_attention_decode`` is the serving-shaped variant: q_len == 1
against a long KV cache with a *dynamic* valid length.  The q row stays
resident in VMEM while the grid sweeps KV blocks; blocks past the cache
length are skipped at runtime (predicated), so decode cost tracks the
actual cache fill, not the allocated ring size.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:                                     # TPU scratch namespace
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                        # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_q: int, block_k: int, causal: bool, scale: float,
            n_kv_blocks: int, kv_len: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            s = jnp.where(k_pos > q_pos, NEG_INF, s)
        if kv_len is not None:                      # padded KV tail
            s = jnp.where(k_pos >= kv_len, NEG_INF, s)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    # skip blocks strictly above the diagonal and blocks entirely inside
    # the padded KV tail; block 0 always holds a live column, so m/l are
    # finite before any fully-masked block can contribute exp(0) garbage.
    live = True
    if causal:
        live = ki * block_k <= qi * block_q + block_q - 1
    if kv_len is not None:
        live = jnp.logical_and(live, ki * block_k < kv_len)
    if live is True:
        _body()
    else:
        pl.when(live)(_body)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _pad_axis1(x: jax.Array, pad: int) -> jax.Array:
    return jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False
                    ) -> jax.Array:
    """q, k, v: (BH, S, D) with equal head counts (GQA handled in ops.py).

    ``sq``/``sk`` need not divide the block sizes: inputs are padded to
    the block grid and the pad is masked inside the kernel.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = max(1, min(block_q, sq))
    block_k = max(1, min(block_k, sk))
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    q = _pad_axis1(q, pad_q)
    k = _pad_axis1(k, pad_k)
    v = _pad_axis1(v, pad_k)
    nq, nk = (sq + pad_q) // block_q, (sk + pad_k) // block_k
    scale = 1.0 / math.sqrt(d)

    kern = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, causal=causal,
        scale=scale, n_kv_blocks=nk, kv_len=sk if pad_k else None)
    scratch = [
        _VMEM((block_q, d), jnp.float32),
        _VMEM((block_q,), jnp.float32),
        _VMEM((block_q,), jnp.float32),
    ] if _VMEM is not None else [
        pl.MemorySpace.ANY,  # pragma: no cover (non-TPU build)
    ]
    out = pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pad_q, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq] if pad_q else out


# --- decode variant (q_len == 1, long KV, dynamic fill) -----------------------

def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, block_k: int, n_kv_blocks: int):
    ki = pl.program_id(1)
    kv_len = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[...].astype(jnp.float32)          # (1, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (1, bk)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, k.shape[0]), 1)
        s = jnp.where(k_pos >= kv_len, NEG_INF, s)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    # blocks entirely past the cache fill are skipped at runtime
    pl.when(ki * block_k < kv_len)(_body)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_attention_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                           kv_len: jax.Array, *, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, D); k, v: (BH, S, D); kv_len: scalar int32 valid prefix.

    The kernel scales the Q row by 1/sqrt(d) once up front (cheaper than
    rescaling every score block).  S is padded to the block grid; both the
    pad and positions >= ``kv_len`` are masked via the same comparison.
    """
    bh, d = q.shape
    sk = k.shape[1]
    block_k = max(1, min(block_k, sk))
    pad_k = (-sk) % block_k
    k = _pad_axis1(k, pad_k)
    v = _pad_axis1(v, pad_k)
    nk = (sk + pad_k) // block_k
    q = (q.astype(jnp.float32) / math.sqrt(d)).astype(q.dtype)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)

    kern = functools.partial(_decode_kernel, block_k=block_k, n_kv_blocks=nk)
    scratch = [
        _VMEM((1, d), jnp.float32),
        _VMEM((1,), jnp.float32),
        _VMEM((1,), jnp.float32),
    ] if _VMEM is not None else [
        pl.MemorySpace.ANY,  # pragma: no cover (non-TPU build)
    ]
    return pl.pallas_call(
        kern,
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (0,)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(kv_len, q, k, v)
