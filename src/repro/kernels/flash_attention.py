"""Flash attention Pallas kernel (TPU target, interpret-validated on CPU).

Blockwise online-softmax attention (Flash-Attention-2 recurrence) tiled for
the TPU memory hierarchy:

  * grid = (batch*heads, q_blocks, kv_blocks); the kv dimension is minor
    (sequential on a TensorCore), so the fp32 accumulators for one q block
    live in VMEM scratch across the kv sweep.
  * BlockSpecs stage (block_q x head_dim) / (block_k x head_dim) tiles of
    Q/K/V from HBM into VMEM; head_dim (64/80/128 here) stays unsplit so
    the MXU sees full contraction dims; block sizes default to 128 —
    MXU-aligned (128x128 systolic array).
  * causal masking is done with iota comparisons inside the block; blocks
    entirely above the diagonal are skipped via ``pl.when`` (the FLOP
    saving XLA's dense attention cannot express).

The kernel computes one (q_block, head) tile per grid step:
    m_new = max(m, rowmax(S));  l = l*corr + rowsum(P);  acc = acc*corr + P V
with S = Q K^T / sqrt(d) in fp32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:                                     # TPU scratch namespace
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                        # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_q: int, block_k: int, causal: bool, scale: float,
            n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(k_pos > q_pos, NEG_INF, s)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False
                    ) -> jax.Array:
    """q, k, v: (BH, S, D) with equal head counts (GQA handled in ops.py)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(d)

    kern = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, causal=causal,
        scale=scale, n_kv_blocks=nk)
    scratch = [
        _VMEM((block_q, d), jnp.float32),
        _VMEM((block_q,), jnp.float32),
        _VMEM((block_q,), jnp.float32),
    ] if _VMEM is not None else [
        pl.MemorySpace.ANY,  # pragma: no cover (non-TPU build)
    ]
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
