"""Jit'd public wrappers around the Pallas kernels.

Handles layout adaptation (model tensors are (B, S, H, D); kernels take
flattened (B*H, S, D)), GQA head replication, and the interpret-mode
fallback: on a CPU backend (this container) kernels execute via
``interpret=True``, which runs the same kernel body under the Pallas
interpreter — numerics identical, used by tests; on TPU they compile to
Mosaic.

Block sizes: passing explicit ints pins the tiling; ``None`` (default)
uses the MXU-aligned defaults, or — when autotuning is on (the
``REPRO_KERNEL_AUTOTUNE=1`` env switch or ``block=\"auto\"``) — the
per-(op, shape, dtype, chip) winner from ``autotune.py``'s persistent
cache.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels import autotune as at
from repro.kernels import flash_attention as fa
from repro.kernels import fused as fused_mod
from repro.kernels import rmsnorm as rn
from repro.kernels import ssd as ssd_mod

BlockArg = Union[int, str, None]          # int | "auto" | None


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tune(block: BlockArg) -> bool:
    return block == "auto" or (block is None and at.enabled())


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: BlockArg = None,
                    block_k: BlockArg = None) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, K, D) with H % K == 0 -> (B, S, H, D)."""
    b, s, h, d = q.shape
    sk = k.shape[1]
    kheads = k.shape[2]
    if kheads != h:                       # GQA: replicate KV heads
        rep = h // kheads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    if _tune(block_q) or _tune(block_k):
        cfg = at.tune_flash_attention(qt, kt, vt, causal=causal,
                                      interpret=_interpret())
        block_q, block_k = cfg["block_q"], cfg["block_k"]
    bq = block_q if isinstance(block_q, int) else 128
    bk = block_k if isinstance(block_k, int) else 128
    o = fa.flash_attention(qt, kt, vt, causal=causal, block_q=bq,
                           block_k=bk, interpret=_interpret())
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def flash_attention_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           cache_len: jax.Array,
                           block_k: BlockArg = None) -> jax.Array:
    """Decode-shaped attention: q: (B, 1, H, D); k, v: (B, S, K, D) caches;
    ``cache_len`` the (dynamic) valid prefix. -> (B, 1, H, D)."""
    b, one, h, d = q.shape
    assert one == 1, q.shape
    s = k.shape[1]
    kheads = k.shape[2]
    if kheads != h:
        rep = h // kheads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.reshape(b, h, d).reshape(b * h, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    bk = block_k if isinstance(block_k, int) else 128
    o = fa.flash_attention_decode(qt, kt, vt, cache_len, block_k=bk,
                                  interpret=_interpret())
    return o.reshape(b, h, d)[:, None].reshape(b, 1, h, d)


def ssd_scan(x, dt, a, b, c, *, chunk: BlockArg = None):
    if _tune(chunk):
        chunk = at.tune_ssd_scan(x, dt, a, b, c,
                                 interpret=_interpret())["chunk"]
    ck = chunk if isinstance(chunk, int) else 128
    return ssd_mod.ssd_scan(x, dt, a, b, c, chunk=ck,
                            interpret=_interpret())


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: BlockArg = None):
    if _tune(block_rows):
        block_rows = at.tune_rmsnorm(x, scale, eps=eps,
                                     interpret=_interpret())["block_rows"]
    br = block_rows if isinstance(block_rows, int) else 256
    return rn.rmsnorm(x, scale, eps=eps, block_rows=br,
                      interpret=_interpret())


def fused_add_rmsnorm(x, res, scale, *, eps: float = 1e-5,
                      block_rows: BlockArg = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """Returns (rmsnorm(x + res) * scale, x + res) in one HBM pass."""
    if _tune(block_rows):
        block_rows = at.tune_fused_add_rmsnorm(
            x, res, scale, eps=eps,
            interpret=_interpret())["block_rows"]
    br = block_rows if isinstance(block_rows, int) else 256
    return fused_mod.fused_add_rmsnorm(x, res, scale, eps=eps,
                                       block_rows=br,
                                       interpret=_interpret())
