"""Jit'd public wrappers around the Pallas kernels.

Handles layout adaptation (model tensors are (B, S, H, D); kernels take
flattened (B*H, S, D)), GQA head replication, and the interpret-mode
fallback: on a CPU backend (this container) kernels execute via
``interpret=True``, which runs the same kernel body under the Pallas
interpreter — numerics identical, used by tests; on TPU they compile to
Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import rmsnorm as rn
from repro.kernels import ssd as ssd_mod


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, K, D) with H % K == 0 -> (B, S, H, D)."""
    b, s, h, d = q.shape
    kheads = k.shape[2]
    if kheads != h:                       # GQA: replicate KV heads
        rep = h // kheads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    o = fa.flash_attention(qt, kt, vt, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=_interpret())
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128):
    return ssd_mod.ssd_scan(x, dt, a, b, c, chunk=chunk,
                            interpret=_interpret())


def rmsnorm(x, scale, *, eps: float = 1e-5):
    return rn.rmsnorm(x, scale, eps=eps, interpret=_interpret())
