"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Self-contained on purpose: these are the ground truth the kernels are swept
against in tests/test_kernels.py, independent of the model code.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D). fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / math.sqrt(d)
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (fp32).

    x: (B, S, H, P); dt: (B, S, H) (positive); a: (H,) negative;
    b, c: (B, S, N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    f32 = jnp.float32

    def step(st, inp):
        xt, dtt, bt, ct = inp
        dec = jnp.exp(dtt.astype(f32) * a)                    # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt.astype(f32),
                         xt.astype(f32), bt.astype(f32))
        st = dec[..., None, None] * st + upd
        yt = jnp.einsum("bn,bhpn->bhp", ct.astype(f32), st)
        return st, yt

    st0 = jnp.zeros((bs, h, p, n), f32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    st, ys = jax.lax.scan(step, st0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), st


def rmsnorm_ref(x: jax.Array, scale: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def fused_add_rmsnorm_ref(x: jax.Array, res: jax.Array, scale: jax.Array,
                          eps: float = 1e-5) -> Tuple[jax.Array, jax.Array]:
    """(rmsnorm(x + res) * scale, x + res) — the unfused two-pass truth."""
    y = (x.astype(jnp.float32) + res.astype(jnp.float32)).astype(x.dtype)
    return rmsnorm_ref(y, scale, eps), y


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len) -> jax.Array:
    """q: (BH, D); k, v: (BH, S, D); positions >= kv_len masked out."""
    d = q.shape[-1]
    s = jnp.einsum("bd,bsd->bs", q, k).astype(jnp.float32) / math.sqrt(d)
    mask = jnp.arange(k.shape[1])[None] >= kv_len
    s = jnp.where(mask, -1e30, s)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bs,bsd->bd", p.astype(v.dtype), v).astype(q.dtype)
