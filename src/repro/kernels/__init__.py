# Pallas kernel layer (TPU target, interpret-validated on CPU):
#   flash_attention.py  training + decode-shaped attention kernels
#   rmsnorm.py / fused.py  RMSNorm and fused residual-add+RMSNorm
#   ssd.py              Mamba-2 chunked SSD scan
#   autotune.py         block-size autotuner w/ persistent on-disk cache
#   ops.py              public (B,S,H,D) wrappers + autotune dispatch
#   ref.py              pure-jnp oracles the kernels are swept against
# `measured.calibrate_kernels` benchmarks these into per-(op, shape,
# dtype, chip) cost tables the analytic profiler interpolates.
