"""Block-size autotuner with a persistent on-disk cache.

Picking Pallas tile sizes analytically (128 everywhere, MXU-shaped) is
right on average and wrong per shape: short sequences want smaller
``block_k`` so the causal skip fires more often, ragged row counts want
``block_rows`` near the remainder, and interpret mode (this container)
has per-grid-step overhead that favors the largest tile that fits.  The
autotuner benchmarks a small candidate grid once per
``(op, shape, dtype, chip)`` and remembers the winner on disk, so every
later process — tests, benchmarks, ``calibrate_kernels`` — reuses it
without re-timing.

Determinism: the cache key includes a fingerprint of the candidate grid,
so the same grid always resolves to the same stored winner; a fresh tune
breaks timing ties by candidate order (first-best wins), and candidates
whose benchmark raises (infeasible tiling) are skipped, not fatal.

Cache file schema (JSON, one file per chip by default)::

    { "<op>|<dtype>|<chip>|s<shape>|g<grid-fp>":
        {"config": {...}, "time_s": 1.2e-4, "tuned": [[{...}, t], ...]} }

``tuned`` keeps every candidate's time for later inspection (the bench
prints it); only ``config`` is consulted on the hot path.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

Config = Dict[str, int]


def default_chip() -> str:
    """Cache identity of the device the kernels actually run on."""
    if jax.default_backend() == "tpu":     # pragma: no cover (no TPU here)
        return jax.devices()[0].device_kind.replace(" ", "-").lower()
    return "cpu-host"


def enabled() -> bool:
    """ops.py consults this for implicit (block size = None) autotuning."""
    return os.environ.get("REPRO_KERNEL_AUTOTUNE", "0") not in ("", "0")


def default_cache_path(chip: Optional[str] = None) -> Path:
    root = Path(os.environ.get("REPRO_KERNEL_CACHE_DIR",
                               Path.home() / ".cache" / "repro-kernels"))
    return root / f"autotune-{chip or default_chip()}.json"


def _grid_fingerprint(candidates: Sequence[Config]) -> str:
    blob = json.dumps([sorted(c.items()) for c in candidates])
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def cache_key(op: str, shape: Tuple[int, ...], dtype: str, chip: str,
              candidates: Sequence[Config]) -> str:
    sh = "x".join(str(int(s)) for s in shape)
    return f"{op}|{dtype}|{chip}|s{sh}|g{_grid_fingerprint(candidates)}"


class AutotuneCache:
    """Persistent winner store; loads eagerly, saves atomically."""

    def __init__(self, path: os.PathLike):
        self.path = Path(path)
        self._data: Dict[str, Dict[str, Any]] = {}
        if self.path.exists():
            try:
                self._data = json.loads(self.path.read_text())
            except (json.JSONDecodeError, OSError):
                self._data = {}            # corrupt cache: retune

    def get(self, key: str) -> Optional[Config]:
        ent = self._data.get(key)
        return dict(ent["config"]) if ent else None

    def put(self, key: str, config: Config, time_s: float,
            tuned: List[Tuple[Config, float]]) -> None:
        self._data[key] = {"config": dict(config), "time_s": time_s,
                           "tuned": [[dict(c), t] for c, t in tuned]}
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(self._data, indent=1, sort_keys=True))
        os.replace(tmp, self.path)


@functools.lru_cache(maxsize=8)
def _shared_cache(path: str) -> AutotuneCache:
    return AutotuneCache(Path(path))


def bench_time(fn: Callable[[], Any], *, warmup: int = 1,
               iters: int = 3) -> float:
    """Median wall-clock of ``fn()`` (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def autotune(op: str, shape: Tuple[int, ...], dtype: str,
             candidates: Sequence[Config],
             bench: Callable[[Config], float], *,
             chip: Optional[str] = None,
             cache: Optional[AutotuneCache] = None) -> Config:
    """Return the fastest candidate config, consulting/updating the cache.

    ``bench(config) -> seconds``; raising marks the candidate infeasible.
    The winner is min by (time, candidate order) — deterministic given the
    measured times, and permanently deterministic once cached.
    """
    if not candidates:
        raise ValueError(f"autotune({op}): empty candidate grid")
    chip = chip or default_chip()
    if cache is None:
        cache = _shared_cache(str(default_cache_path(chip)))
    key = cache_key(op, shape, dtype, chip, candidates)
    hit = cache.get(key)
    if hit is not None:
        return hit
    tuned: List[Tuple[Config, float]] = []
    for cand in candidates:
        try:
            tuned.append((cand, bench(cand)))
        except Exception:
            continue                       # infeasible tiling
    if not tuned:
        raise RuntimeError(f"autotune({op}): no feasible candidate "
                           f"for shape={shape}")
    best_i = min(range(len(tuned)), key=lambda i: (tuned[i][1], i))
    best, t = tuned[best_i]
    cache.put(key, best, t, tuned)
    return dict(best)


# --- per-op candidate grids + tuners (used by ops.py and the bench) -----------

def flash_candidates(sq: int, sk: int) -> List[Config]:
    qs = sorted({min(b, sq) for b in (64, 128, 256)})
    ks = sorted({min(b, sk) for b in (64, 128, 256)})
    return [{"block_q": bq, "block_k": bk} for bq in qs for bk in ks]


def rows_candidates(rows: int) -> List[Config]:
    return [{"block_rows": b}
            for b in sorted({min(b, rows) for b in (64, 128, 256, 512)})]


def chunk_candidates(s: int) -> List[Config]:
    return [{"chunk": c} for c in sorted({min(c, s) for c in (64, 128, 256)})]


def tune_flash_attention(q, k, v, *, causal: bool, interpret: bool,
                         cache: Optional[AutotuneCache] = None) -> Config:
    from repro.kernels import flash_attention as fa
    bh, sq, d = q.shape
    sk = k.shape[1]

    def bench(c: Config) -> float:
        return bench_time(lambda: fa.flash_attention(
            q, k, v, causal=causal, interpret=interpret, **c))

    return autotune("flash_attention", (bh, sq, sk, d, int(causal)),
                    str(q.dtype), flash_candidates(sq, sk), bench,
                    cache=cache)


def tune_rmsnorm(x, scale, *, eps: float, interpret: bool,
                 cache: Optional[AutotuneCache] = None) -> Config:
    from repro.kernels import rmsnorm as rn
    rows = 1
    for s in x.shape[:-1]:
        rows *= s

    def bench(c: Config) -> float:
        return bench_time(lambda: rn.rmsnorm(
            x, scale, eps=eps, interpret=interpret, **c))

    return autotune("rmsnorm", (rows, x.shape[-1]), str(x.dtype),
                    rows_candidates(rows), bench, cache=cache)


def tune_fused_add_rmsnorm(x, res, scale, *, eps: float, interpret: bool,
                           cache: Optional[AutotuneCache] = None) -> Config:
    from repro.kernels import fused
    rows = 1
    for s in x.shape[:-1]:
        rows *= s

    def bench(c: Config) -> float:
        return bench_time(lambda: fused.fused_add_rmsnorm(
            x, res, scale, eps=eps, interpret=interpret, **c))

    return autotune("fused_add_rmsnorm", (rows, x.shape[-1]), str(x.dtype),
                    rows_candidates(rows), bench, cache=cache)


def tune_ssd_scan(x, dt, a, b, c, *, interpret: bool,
                  cache: Optional[AutotuneCache] = None) -> Config:
    from repro.kernels import ssd as ssd_mod
    bs, s, h, p = x.shape

    def bench(cand: Config) -> float:
        return bench_time(lambda: ssd_mod.ssd_scan(
            x, dt, a, b, c, interpret=interpret, **cand))

    return autotune("ssd_scan", (bs, s, h, p, b.shape[-1]), str(x.dtype),
                    chunk_candidates(s), bench, cache=cache)
