"""Mamba-2 SSD chunked-scan Pallas kernel (TPU target).

State-space duality (arXiv:2405.21060): within a chunk the output is an
"attention-like" quadratic form; across chunks a recurrent state (P x N)
flows.  TPU mapping:

  * grid = (batch, heads, n_chunks); chunks are the minor (sequential)
    dimension, so the running state lives in VMEM scratch across the chunk
    sweep for one (batch, head) — the recurrence never touches HBM.
  * per grid step the kernel stages (chunk x P) inputs and (chunk x N)
    B/C projections into VMEM; the two einsums (scores C·B^T and the
    state update x^T·B) are MXU matmuls; decay weights are VPU elementwise.
  * chunk length defaults to 128 (MXU-aligned); P=64..128, N=64..128 fit
    VMEM comfortably: working set ~ chunk*(P+2N)*4B + P*N*4B ≈ 200 KB.

Outputs y(chunk x P) plus the final state per (b, h).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                         # pragma: no cover
    _VMEM = None


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref, state_ref,
            *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # (Q,)
    a = a_ref[0]                                  # scalar decay rate (<0)
    b = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)           # (Q, N)

    da = dt * a                                   # (Q,)
    cum = jnp.cumsum(da)                          # (Q,)
    # within-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ldec = jnp.where(iota_i >= iota_j, jnp.exp(li), 0.0)
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (Q, Q)
    w = scores * ldec * dt[None, :]                # weight on x_j
    y_diag = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (Q, P)

    # cross-chunk: y_off = (C decayed) @ state^T  (state: (P, N))
    st = state_ref[...]
    c_dec = c * jnp.exp(cum)[:, None]
    y_off = jax.lax.dot_general(
        c_dec, st, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (Q, P)
    y_ref[0, 0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: state = exp(cum_last) * state + sum_j w_j x_j b_j^T
    dec_end = jnp.exp(cum[-1] - cum) * dt          # (Q,)
    xw = x * dec_end[:, None]                      # (Q, P)
    upd = jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (P, N)
    state_ref[...] = jnp.exp(cum[-1]) * st + upd

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        st_out_ref[0, 0] = state_ref[...].astype(st_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); a: (H,); b, c: (B, S, N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).  S need not divide
    ``chunk``: the tail is zero-padded, and padded steps are exact
    no-ops on the recurrence (dt = 0 -> decay exp(0) = 1, update 0), so
    the final state is unaffected and padded y rows are sliced off."""
    bs, s_orig, h, p = x.shape
    n = b.shape[-1]
    chunk = max(1, min(chunk, s_orig))
    pad = (-s_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // chunk
    # layout: (B, H, nc, Q, ...) so (b, h) are grid-major, chunks minor
    xr = x.transpose(0, 2, 1, 3).reshape(bs, h, nc, chunk, p)
    dtr = dt.transpose(0, 2, 1).reshape(bs, h, nc, chunk)
    br = b.reshape(bs, nc, chunk, n)
    cr = c.reshape(bs, nc, chunk, n)

    kern = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    scratch = [_VMEM((p, n), jnp.float32)] if _VMEM is not None else []
    y, st = pl.pallas_call(
        kern,
        grid=(bs, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, h, nc, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((bs, h, p, n), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(xr, dtr, a.astype(jnp.float32), br, cr)
    y = y.reshape(bs, h, s, p).transpose(0, 2, 1, 3)
    return (y[:, :s_orig] if pad else y), st
