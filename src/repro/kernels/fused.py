"""Fused residual-add + RMSNorm Pallas kernel.

The pre-norm transformer repeats ``y = x + delta; h = rmsnorm(y)`` at
every sub-block boundary.  Unfused, XLA materializes ``y`` to HBM and the
norm reads it straight back: three HBM passes over the hidden stream
(write y, read y, write h) on top of the two operand reads.  This kernel
emits both outputs from one pass — read x and delta once, keep the sum in
VMEM, reduce/normalize there, write ``y`` and ``h`` — saving one full HBM
read of the hidden state per fusion site.

Same tiling discipline as ``rmsnorm.py``: rows x d tiles, scale hoisted
into VMEM scratch on the first grid step, ragged row counts handled by an
exact-remainder second call instead of dead padded tiles.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:                         # pragma: no cover
    _VMEM = None


def _kernel(x_ref, r_ref, s_ref, y_ref, o_ref, scale_ref, *, eps: float):
    @pl.when(pl.program_id(0) == 0)
    def _hoist():
        scale_ref[...] = s_ref[...].astype(jnp.float32)

    y = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    o_ref[...] = ((y * jax.lax.rsqrt(var + eps))
                  * scale_ref[...]).astype(o_ref.dtype)


def _fused_rows(xf, rf, scale, eps: float, br: int, interpret: bool):
    rows, d = xf.shape
    scratch = ([_VMEM((d,), jnp.float32)] if _VMEM is not None
               else [pl.MemorySpace.ANY])  # pragma: no cover (non-TPU)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xf.shape, xf.dtype),
            jax.ShapeDtypeStruct(xf.shape, xf.dtype),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(xf, rf, scale)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def fused_add_rmsnorm(x: jax.Array, res: jax.Array, scale: jax.Array, *,
                      eps: float = 1e-5, block_rows: int = 256,
                      interpret: bool = False
                      ) -> Tuple[jax.Array, jax.Array]:
    """x, res: (..., d); scale: (d,).  Returns (normed, x + res)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    rf = res.reshape(rows, d)
    br = min(block_rows, rows)
    full = rows - rows % br
    ys: List[jax.Array] = []
    os: List[jax.Array] = []
    if full:
        y, o = _fused_rows(xf[:full], rf[:full], scale, eps, br, interpret)
        ys.append(y)
        os.append(o)
    if rows - full:
        y, o = _fused_rows(xf[full:], rf[full:], scale, eps, rows - full,
                           interpret)
        ys.append(y)
        os.append(o)
    y = ys[0] if len(ys) == 1 else jnp.concatenate(ys)
    o = os[0] if len(os) == 1 else jnp.concatenate(os)
    return o.reshape(orig_shape), y.reshape(orig_shape)
